//! VM instance lifecycle.
//!
//! An [`Instance`] models one spot (or on-demand) VM: provisioning →
//! running → (notice received) → evicted/deallocated. The scale set
//! ([`super::scale_set`]) owns creation and replacement; the billing meter
//! books uptime on termination.

use crate::simclock::SimTime;

/// Opaque instance identifier, unique per experiment.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct InstanceId(pub u64);

impl std::fmt::Display for InstanceId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "vm-{}", self.0)
    }
}

/// Lifecycle state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InstanceState {
    /// Being created by the scale set; not yet running workloads.
    Provisioning,
    /// Up and billable.
    Running,
    /// Eviction notice delivered; still running until the deadline.
    Noticed,
    /// Terminated (evicted or completed); no longer billable.
    Terminated,
}

/// One virtual machine.
#[derive(Debug, Clone)]
pub struct Instance {
    pub id: InstanceId,
    pub vm_size: String,
    pub spot: bool,
    pub state: InstanceState,
    /// When the VM entered `Running`.
    pub started_at: SimTime,
    /// When the VM was terminated (for uptime billing).
    pub terminated_at: Option<SimTime>,
}

impl Instance {
    pub fn new(id: InstanceId, vm_size: &str, spot: bool, now: SimTime) -> Self {
        Self {
            id,
            vm_size: vm_size.to_string(),
            spot,
            state: InstanceState::Running,
            started_at: now,
            terminated_at: None,
        }
    }

    pub fn is_running(&self) -> bool {
        matches!(self.state, InstanceState::Running | InstanceState::Noticed)
    }

    /// Mark the eviction notice as delivered.
    pub fn notice(&mut self) {
        assert_eq!(
            self.state,
            InstanceState::Running,
            "notice on non-running instance {}",
            self.id
        );
        self.state = InstanceState::Noticed;
    }

    /// Terminate at `now`; returns billable uptime.
    pub fn terminate(&mut self, now: SimTime) -> crate::simclock::SimDuration {
        assert!(
            self.is_running(),
            "terminate on non-running instance {}",
            self.id
        );
        self.state = InstanceState::Terminated;
        self.terminated_at = Some(now);
        now.since(self.started_at)
    }

    /// Uptime so far (or final uptime once terminated).
    pub fn uptime(&self, now: SimTime) -> crate::simclock::SimDuration {
        match self.terminated_at {
            Some(t) => t.since(self.started_at),
            None => now.since(self.started_at),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simclock::SimDuration;

    #[test]
    fn lifecycle() {
        let mut vm = Instance::new(
            InstanceId(1),
            "Standard_D8s_v3",
            true,
            SimTime::from_secs(100),
        );
        assert!(vm.is_running());
        vm.notice();
        assert_eq!(vm.state, InstanceState::Noticed);
        assert!(vm.is_running());
        let uptime = vm.terminate(SimTime::from_secs(400));
        assert_eq!(uptime, SimDuration::from_secs(300));
        assert!(!vm.is_running());
        assert_eq!(vm.uptime(SimTime::from_secs(999)).as_secs(), 300);
    }

    #[test]
    #[should_panic(expected = "notice on non-running")]
    fn cannot_notice_terminated() {
        let mut vm =
            Instance::new(InstanceId(2), "D8s", true, SimTime::ZERO);
        vm.terminate(SimTime::from_secs(1));
        vm.notice();
    }

    #[test]
    fn display_id() {
        assert_eq!(InstanceId(7).to_string(), "vm-7");
    }
}
