//! Eviction plans: when the spot market reclaims an instance.
//!
//! Real spot evictions are unpredictable, so the paper injects them with
//! `az vmss simulate-eviction` at fixed intervals (Table I: every 60 or
//! 90 minutes). [`EvictionPlan`] generalizes that: fixed interval
//! (the paper's methodology), Poisson arrivals (spot-market model used by
//! the ablation benches), and empirical traces. Offsets are measured from
//! each instance's start, matching how the paper schedules its injections.

use crate::config::EvictionPlanCfg;
use crate::simclock::SimDuration;
use crate::util::Prng;

/// Stateful eviction-time generator for a sequence of instances.
#[derive(Debug, Clone)]
pub struct EvictionPlan {
    cfg: EvictionPlanCfg,
    rng: Prng,
    /// Index of the next instance (trace plans consume one offset per
    /// instance; fixed/poisson draw independently per instance).
    instance_idx: usize,
}

impl EvictionPlan {
    pub fn new(cfg: EvictionPlanCfg, seed: u64) -> Self {
        Self { cfg, rng: Prng::new(seed ^ 0xE71C_7105), instance_idx: 0 }
    }

    /// Uptime offset at which the *next* instance will receive its
    /// eviction notice, or `None` if it will never be evicted. Call once
    /// per instance, in creation order.
    pub fn next_eviction_offset(&mut self) -> Option<SimDuration> {
        let idx = self.instance_idx;
        self.instance_idx += 1;
        match &self.cfg {
            EvictionPlanCfg::None => None,
            EvictionPlanCfg::Fixed { interval } => Some(*interval),
            EvictionPlanCfg::Poisson { mean } => Some(
                SimDuration::from_secs_f64(
                    self.rng.exp(mean.as_secs_f64()).max(1.0),
                ),
            ),
            EvictionPlanCfg::Trace { offsets } => offsets.get(idx).copied(),
        }
    }

    pub fn cfg(&self) -> &EvictionPlanCfg {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{forall, shrink_none, Config};

    #[test]
    fn none_never_evicts() {
        let mut p = EvictionPlan::new(EvictionPlanCfg::None, 1);
        for _ in 0..5 {
            assert_eq!(p.next_eviction_offset(), None);
        }
    }

    #[test]
    fn fixed_matches_paper_injection() {
        let mut p = EvictionPlan::new(
            EvictionPlanCfg::Fixed { interval: SimDuration::from_mins(90) },
            1,
        );
        for _ in 0..4 {
            assert_eq!(
                p.next_eviction_offset(),
                Some(SimDuration::from_mins(90))
            );
        }
    }

    #[test]
    fn trace_consumed_in_order_then_exhausted() {
        let offsets =
            vec![SimDuration::from_mins(10), SimDuration::from_mins(45)];
        let mut p =
            EvictionPlan::new(EvictionPlanCfg::Trace { offsets: offsets.clone() }, 1);
        assert_eq!(p.next_eviction_offset(), Some(offsets[0]));
        assert_eq!(p.next_eviction_offset(), Some(offsets[1]));
        assert_eq!(p.next_eviction_offset(), None);
    }

    #[test]
    fn poisson_mean_and_determinism() {
        let mean = SimDuration::from_mins(60);
        let sample = |seed: u64| -> Vec<u64> {
            let mut p = EvictionPlan::new(
                EvictionPlanCfg::Poisson { mean },
                seed,
            );
            (0..2000)
                .map(|_| p.next_eviction_offset().unwrap().as_millis())
                .collect()
        };
        let a = sample(9);
        let b = sample(9);
        assert_eq!(a, b, "same seed must replay identically");
        let avg =
            a.iter().map(|&ms| ms as f64).sum::<f64>() / a.len() as f64 / 60_000.0;
        assert!((avg - 60.0).abs() < 4.0, "poisson mean off: {avg} min");
    }

    #[test]
    fn prop_offsets_always_positive() {
        forall(
            Config::default().cases(100),
            |rng| (rng.next_u64(), rng.range_u64(1, 10_000)),
            shrink_none,
            |&(seed, mean_secs)| {
                let mut p = EvictionPlan::new(
                    EvictionPlanCfg::Poisson {
                        mean: SimDuration::from_secs(mean_secs),
                    },
                    seed,
                );
                for _ in 0..20 {
                    let off = p.next_eviction_offset().unwrap();
                    if off.is_zero() {
                        return Err("zero eviction offset".into());
                    }
                }
                Ok(())
            },
        );
    }
}
