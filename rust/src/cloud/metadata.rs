//! Scheduled-events metadata service (Azure IMDS analog, paper §III-B).
//!
//! Azure exposes upcoming platform events — including spot `Preempt` — at
//! a non-routable endpoint inside the VM:
//!
//! ```text
//! GET http://169.254.169.254/metadata/scheduledevents?api-version=2020-07-01
//! ```
//!
//! returning a JSON document with a `DocumentIncarnation` counter and an
//! `Events` array; a VM acknowledges readiness by POSTing
//! `{"StartRequests": [{"EventId": …}]}`. The eviction notice gives a
//! minimum of 30 s (`NotBefore`).
//!
//! This module is the in-process service: the same document schema, the
//! same ack protocol, driven by the virtual clock. [`super::imds_http`]
//! exposes it over a real localhost HTTP endpoint for real-time mode, so
//! the coordinator's monitor exercises the identical wire format the
//! Azure integration would.

use crate::json::Value;
use crate::simclock::SimTime;
use std::collections::BTreeMap;

/// Event lifecycle status (subset Azure exposes for Preempt).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventStatus {
    /// Announced; the VM may prepare until `NotBefore`.
    Scheduled,
    /// The VM acknowledged (StartRequests) — the platform may proceed
    /// immediately.
    Started,
}

impl EventStatus {
    pub fn as_str(self) -> &'static str {
        match self {
            EventStatus::Scheduled => "Scheduled",
            EventStatus::Started => "Started",
        }
    }
}

/// One scheduled event.
#[derive(Debug, Clone)]
pub struct ScheduledEvent {
    pub event_id: String,
    pub event_type: String, // "Preempt" | "Reboot" | "Redeploy" | "Terminate"
    pub resource: String,   // instance name
    pub status: EventStatus,
    pub not_before: SimTime,
}

impl ScheduledEvent {
    fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("EventId", self.event_id.as_str())
            .set("EventType", self.event_type.as_str())
            .set("ResourceType", "VirtualMachine")
            .set("Resources", vec![self.resource.as_str()])
            .set("EventStatus", self.status.as_str())
            // Azure renders an HTTP-date; the simulator's timeline is
            // virtual, so we publish the virtual instant in both a human
            // form and a machine-readable millisecond mirror.
            .set("NotBefore", format!("{:?}", self.not_before))
            .set("NotBeforeMs", self.not_before.as_millis())
            .set("EventSource", "Platform")
            .set("DurationInSeconds", -1i64);
        v
    }
}

/// The per-scale-set scheduled-events service.
///
/// Event ids are drawn from a per-service counter (not a process-global
/// sequence): ids only need to be unique within one service's document,
/// and a local counter makes every seeded run's timeline byte-identical
/// regardless of process history or how many sweep threads are running
/// other experiments concurrently.
#[derive(Debug, Default)]
pub struct MetadataService {
    incarnation: u64,
    next_event_id: u64,
    events: BTreeMap<String, ScheduledEvent>,
    /// Endpoint outage (chaos injection): while set, polls see nothing —
    /// the document is unreachable, not empty, so incarnation tracking in
    /// the monitor is untouched and the notice reappears once the
    /// endpoint recovers.
    unavailable: bool,
}

impl MetadataService {
    pub fn new() -> Self {
        Self::default()
    }

    /// Platform announces a preempt of `resource` effective `not_before`.
    /// Returns the event id.
    pub fn post_preempt(&mut self, resource: &str, not_before: SimTime) -> String {
        self.next_event_id += 1;
        let event_id = format!("evt-{}", self.next_event_id);
        self.events.insert(
            event_id.clone(),
            ScheduledEvent {
                event_id: event_id.clone(),
                event_type: "Preempt".into(),
                resource: resource.to_string(),
                status: EventStatus::Scheduled,
                not_before,
            },
        );
        self.incarnation += 1;
        event_id
    }

    /// The GET document, exactly the IMDS shape.
    pub fn document(&self) -> Value {
        let mut doc = Value::obj();
        doc.set("DocumentIncarnation", self.incarnation);
        doc.set(
            "Events",
            Value::Array(self.events.values().map(|e| e.to_json()).collect()),
        );
        doc
    }

    /// Handle a StartRequests ack body; returns the number of events
    /// acknowledged. Unknown event ids are ignored (Azure semantics).
    pub fn start_requests(&mut self, body: &Value) -> usize {
        let mut n = 0;
        if let Some(reqs) = body.get("StartRequests").and_then(Value::as_array) {
            for r in reqs {
                if let Some(id) = r.get("EventId").and_then(Value::as_str) {
                    if let Some(ev) = self.events.get_mut(id) {
                        if ev.status == EventStatus::Scheduled {
                            ev.status = EventStatus::Started;
                            self.incarnation += 1;
                            n += 1;
                        }
                    }
                }
            }
        }
        n
    }

    /// Platform completed the event (the instance is gone): remove it.
    pub fn complete(&mut self, event_id: &str) {
        if self.events.remove(event_id).is_some() {
            self.incarnation += 1;
        }
    }

    /// Remove all events for a resource (instance terminated).
    pub fn clear_resource(&mut self, resource: &str) {
        let before = self.events.len();
        self.events.retain(|_, e| e.resource != resource);
        if self.events.len() != before {
            self.incarnation += 1;
        }
    }

    pub fn incarnation(&self) -> u64 {
        self.incarnation
    }

    /// Mark the endpoint up/down (chaos: IMDS outage windows).
    pub fn set_available(&mut self, up: bool) {
        self.unavailable = !up;
    }

    /// Is the endpoint reachable right now?
    pub fn is_available(&self) -> bool {
        !self.unavailable
    }

    /// Current events (test/inspection helper).
    pub fn events(&self) -> impl Iterator<Item = &ScheduledEvent> {
        self.events.values()
    }
}

/// Parse the IMDS document into typed events — the client half, used by
/// the coordinator's monitor against both the in-proc service and the
/// HTTP endpoint.
pub fn parse_document(doc: &Value) -> anyhow::Result<(u64, Vec<ScheduledEvent>)> {
    let incarnation = doc.req_u64("DocumentIncarnation")?;
    let mut events = Vec::new();
    for e in doc.req_array("Events")? {
        let status = match e.req_str("EventStatus")? {
            "Scheduled" => EventStatus::Scheduled,
            "Started" => EventStatus::Started,
            other => anyhow::bail!("unknown EventStatus '{other}'"),
        };
        events.push(ScheduledEvent {
            event_id: e.req_str("EventId")?.to_string(),
            event_type: e.req_str("EventType")?.to_string(),
            resource: e
                .req_array("Resources")?
                .first()
                .and_then(Value::as_str)
                .unwrap_or_default()
                .to_string(),
            status,
            not_before: SimTime(e.req_u64("NotBeforeMs")?),
        });
    }
    Ok((incarnation, events))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_document_shape() {
        let svc = MetadataService::new();
        let doc = svc.document();
        assert_eq!(doc.req_u64("DocumentIncarnation").unwrap(), 0);
        assert_eq!(doc.req_array("Events").unwrap().len(), 0);
    }

    #[test]
    fn preempt_round_trips_through_wire_format() {
        let mut svc = MetadataService::new();
        let id = svc.post_preempt("vm-3", SimTime::from_secs(5400));
        let doc = svc.document();
        let (inc, events) = parse_document(&doc).unwrap();
        assert_eq!(inc, 1);
        assert_eq!(events.len(), 1);
        let e = &events[0];
        assert_eq!(e.event_id, id);
        assert_eq!(e.event_type, "Preempt");
        assert_eq!(e.resource, "vm-3");
        assert_eq!(e.status, EventStatus::Scheduled);
        assert_eq!(e.not_before, SimTime::from_secs(5400));
    }

    #[test]
    fn ack_protocol() {
        let mut svc = MetadataService::new();
        let id = svc.post_preempt("vm-0", SimTime::from_secs(100));
        let mut body = Value::obj();
        let mut req = Value::obj();
        req.set("EventId", id.as_str());
        body.set("StartRequests", Value::Array(vec![req]));
        assert_eq!(svc.start_requests(&body), 1);
        // double-ack is a no-op
        assert_eq!(svc.start_requests(&body), 0);
        let (_, events) = parse_document(&svc.document()).unwrap();
        assert_eq!(events[0].status, EventStatus::Started);
    }

    #[test]
    fn unknown_ack_ignored() {
        let mut svc = MetadataService::new();
        let mut body = Value::obj();
        let mut req = Value::obj();
        req.set("EventId", "evt-nope");
        body.set("StartRequests", Value::Array(vec![req]));
        assert_eq!(svc.start_requests(&body), 0);
    }

    #[test]
    fn event_ids_are_per_service_deterministic() {
        // Two services issue the same id sequence independently: seeded
        // runs stay byte-identical no matter what else ran first in the
        // process (the sweep determinism invariant).
        let mut a = MetadataService::new();
        let mut b = MetadataService::new();
        let a1 = a.post_preempt("vm-0", SimTime::from_secs(1));
        let a2 = a.post_preempt("vm-1", SimTime::from_secs(2));
        let b1 = b.post_preempt("vm-9", SimTime::from_secs(3));
        assert_eq!(a1, "evt-1");
        assert_eq!(a2, "evt-2");
        assert_eq!(b1, "evt-1");
    }

    #[test]
    fn incarnation_increments_on_every_change() {
        let mut svc = MetadataService::new();
        let base = svc.incarnation();
        let id = svc.post_preempt("vm-1", SimTime::from_secs(1));
        assert_eq!(svc.incarnation(), base + 1);
        svc.complete(&id);
        assert_eq!(svc.incarnation(), base + 2);
        svc.complete(&id); // absent: no change
        assert_eq!(svc.incarnation(), base + 2);
    }

    #[test]
    fn availability_toggle() {
        let mut svc = MetadataService::new();
        assert!(svc.is_available());
        svc.set_available(false);
        assert!(!svc.is_available());
        svc.set_available(true);
        assert!(svc.is_available());
    }

    #[test]
    fn clear_resource_removes_only_matching() {
        let mut svc = MetadataService::new();
        svc.post_preempt("vm-1", SimTime::from_secs(1));
        svc.post_preempt("vm-2", SimTime::from_secs(2));
        svc.clear_resource("vm-1");
        let (_, events) = parse_document(&svc.document()).unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].resource, "vm-2");
    }

    #[test]
    fn parse_rejects_malformed() {
        let doc = crate::json::parse(r#"{"Events": []}"#).unwrap();
        assert!(parse_document(&doc).is_err());
        let doc = crate::json::parse(
            r#"{"DocumentIncarnation": 1, "Events": [{"EventId": "e"}]}"#,
        )
        .unwrap();
        assert!(parse_document(&doc).is_err());
    }
}
