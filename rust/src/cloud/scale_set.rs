//! Scale set: the VM pool manager (Azure "Virtual Machine Scale Sets").
//!
//! The paper deploys workloads through scale sets because they "act as a
//! VM pool manager that is capable of restarting new spot instances upon
//! eviction of existing spot instances" (§III). The paper's runs use
//! capacity 1 (the [`ScaleSet::new`] default): when the current instance
//! is evicted, a replacement enters provisioning and comes up after
//! `provisioning_delay`. Custom Data (the coordinator launch script) is
//! re-run on every new instance — in this codebase that corresponds to
//! the restart path of [`crate::coordinator`].
//!
//! Since the fleet refactor the capacity-1 assumption is no longer baked
//! in: [`ScaleSet::with_capacity`] admits N concurrent instances, and
//! [`ScaleSet::launch_with_id`] lets an owner ([`super::fleet::Fleet`])
//! allocate instance ids across several sets so a multi-pool fleet keeps
//! one experiment-wide id sequence. [`ScaleSet::with_pool_label`] tags the
//! uptime this set books so [`super::billing::BillingMeter`] can attribute
//! cost per pool.

use super::billing::BillingMeter;
use super::instance::{Instance, InstanceId};
use super::pricing::PriceBook;
use crate::simclock::{SimDuration, SimTime};
use anyhow::Result;

/// A pool of up to `capacity` concurrent instances with automatic
/// replacement semantics (capacity 1 by default, the paper's setup).
#[derive(Debug)]
pub struct ScaleSet {
    vm_size: String,
    spot: bool,
    capacity: u32,
    provisioning_delay: SimDuration,
    price_book: PriceBook,
    /// Billing attribution tag when this set is one pool of a fleet.
    pool_label: Option<String>,
    next_id: u64,
    /// Currently-running instances (≤ capacity).
    running: Vec<Instance>,
    /// Total instances launched over the experiment (for reporting).
    launched: u32,
}

impl ScaleSet {
    pub fn new(
        vm_size: &str,
        spot: bool,
        provisioning_delay: SimDuration,
        price_book: PriceBook,
    ) -> Result<Self> {
        // Validate the size exists up front.
        price_book.lookup(vm_size)?;
        Ok(Self {
            vm_size: vm_size.to_string(),
            spot,
            capacity: 1,
            provisioning_delay,
            price_book,
            pool_label: None,
            next_id: 0,
            running: Vec::new(),
            launched: 0,
        })
    }

    /// Allow up to `capacity` concurrent instances (batch-cluster pools).
    pub fn with_capacity(mut self, capacity: u32) -> Self {
        assert!(capacity >= 1, "scale set capacity must be >= 1");
        self.capacity = capacity;
        self
    }

    /// Attribute this set's billed uptime to a named fleet pool.
    pub fn with_pool_label(mut self, label: &str) -> Self {
        self.pool_label = Some(label.to_string());
        self
    }

    /// Launch a new instance, immediately Running at `now`. (The
    /// provisioning delay is charged by the engine between the eviction
    /// and calling this — see [`Self::provisioning_delay`].)
    pub fn launch(&mut self, now: SimTime) -> &Instance {
        let id = InstanceId(self.next_id);
        self.launch_with_id(id, now)
    }

    /// Launch with an externally-allocated id (a fleet keeps one id
    /// sequence across its pools' sets).
    pub fn launch_with_id(&mut self, id: InstanceId, now: SimTime) -> &Instance {
        assert!(
            (self.running.len() as u32) < self.capacity,
            "scale set at capacity ({})",
            self.capacity
        );
        self.next_id = self.next_id.max(id.0 + 1);
        self.launched += 1;
        self.running
            .push(Instance::new(id, &self.vm_size, self.spot, now));
        // spoton-lint: allow(D3, reason = "last() follows the push on the previous line")
        self.running.last().expect("just pushed")
    }

    /// The oldest currently-live instance, if any (the only instance in a
    /// capacity-1 set).
    pub fn current(&self) -> Option<&Instance> {
        self.running.first()
    }

    pub fn current_mut(&mut self) -> Option<&mut Instance> {
        self.running.first_mut()
    }

    /// All currently-running instances.
    pub fn running(&self) -> &[Instance] {
        &self.running
    }

    pub fn running_count(&self) -> usize {
        self.running.len()
    }

    pub fn capacity(&self) -> u32 {
        self.capacity
    }

    /// Terminate the oldest running instance at `now`, booking its uptime.
    pub fn terminate_current(
        &mut self,
        now: SimTime,
        billing: &mut BillingMeter,
    ) -> Option<InstanceId> {
        let id = self.running.first()?.id;
        self.terminate(id, now, billing)
    }

    /// Terminate a specific running instance at `now`, booking its uptime.
    /// Returns `None` if no such instance is running.
    pub fn terminate(
        &mut self,
        id: InstanceId,
        now: SimTime,
        billing: &mut BillingMeter,
    ) -> Option<InstanceId> {
        let idx = self.running.iter().position(|i| i.id == id)?;
        let mut inst = self.running.remove(idx);
        let uptime = inst.terminate(now);
        let size = self
            .price_book
            .lookup(&inst.vm_size)
            // spoton-lint: allow(D3, reason = "capacity validated at construction")
            .expect("validated at construction");
        let price = size.price_per_hour(inst.spot);
        match &self.pool_label {
            Some(pool) => billing.book_instance_in_pool(
                pool,
                &inst.id.to_string(),
                &inst.vm_size,
                inst.spot,
                uptime,
                price,
            ),
            None => billing.book_instance(
                &inst.id.to_string(),
                &inst.vm_size,
                inst.spot,
                uptime,
                price,
            ),
        }
        Some(inst.id)
    }

    /// Remove and terminate the oldest running instance at `now`
    /// **without booking** its uptime — callers whose price varies over
    /// the uptime ([`super::fleet::Fleet`] pools with price traces) book
    /// piecewise themselves via
    /// [`BillingMeter::book_instance_piecewise`].
    pub fn reclaim_current_unbilled(&mut self, now: SimTime) -> Option<Instance> {
        let id = self.running.first()?.id;
        self.reclaim_unbilled(id, now)
    }

    /// Remove and terminate a specific running instance **without
    /// booking** its uptime — the by-id variant of
    /// [`Self::reclaim_current_unbilled`] for capacity-N pools where the
    /// dying instance is not necessarily the oldest.
    pub fn reclaim_unbilled(
        &mut self,
        id: InstanceId,
        now: SimTime,
    ) -> Option<Instance> {
        let idx = self.running.iter().position(|i| i.id == id)?;
        let mut inst = self.running.remove(idx);
        inst.terminate(now);
        Some(inst)
    }

    /// Delay before a replacement instance is Running. (The instant a
    /// replacement is actually Running is the fleet's call —
    /// [`super::fleet::Fleet::ready_at`] — scheduled as an event by the
    /// engine, never a blocking wait.)
    pub fn provisioning_delay(&self) -> SimDuration {
        self.provisioning_delay
    }

    /// Change the VM size for future launches (OOM-resume upsizing,
    /// paper §IV).
    pub fn resize(&mut self, vm_size: &str) -> Result<()> {
        self.price_book.lookup(vm_size)?;
        self.vm_size = vm_size.to_string();
        Ok(())
    }

    pub fn vm_size(&self) -> &str {
        &self.vm_size
    }

    pub fn spot(&self) -> bool {
        self.spot
    }

    pub fn launched(&self) -> u32 {
        self.launched
    }

    pub fn price_book(&self) -> &PriceBook {
        &self.price_book
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk() -> ScaleSet {
        ScaleSet::new(
            "Standard_D8s_v3",
            true,
            SimDuration::from_secs(90),
            PriceBook::default(),
        )
        .unwrap()
    }

    #[test]
    fn launch_terminate_relaunch() {
        let mut ss = mk();
        let mut billing = BillingMeter::new();
        let id0 = ss.launch(SimTime::ZERO).id;
        assert!(ss.current().is_some());
        let tid = ss
            .terminate_current(SimTime::from_secs(3600), &mut billing)
            .unwrap();
        assert_eq!(tid, id0);
        assert!(ss.current().is_none());
        // one spot hour at $0.076
        assert!((billing.total() - 0.076).abs() < 1e-9);
        let id1 = ss.launch(SimTime::from_secs(3690)).id;
        assert_ne!(id0, id1);
        assert_eq!(ss.launched(), 2);
    }

    #[test]
    fn terminate_when_empty_is_none() {
        let mut ss = mk();
        let mut billing = BillingMeter::new();
        assert!(ss.terminate_current(SimTime::ZERO, &mut billing).is_none());
        assert_eq!(billing.total(), 0.0);
    }

    #[test]
    #[should_panic(expected = "at capacity (1)")]
    fn default_capacity_is_one() {
        let mut ss = mk();
        ss.launch(SimTime::ZERO);
        ss.launch(SimTime::from_secs(1));
    }

    #[test]
    fn capacity_n_runs_concurrent_instances() {
        let mut ss = mk().with_capacity(3);
        let mut billing = BillingMeter::new();
        let a = ss.launch(SimTime::ZERO).id;
        let b = ss.launch(SimTime::from_secs(10)).id;
        let c = ss.launch(SimTime::from_secs(20)).id;
        assert_eq!(ss.running_count(), 3);
        assert_eq!(ss.launched(), 3);
        assert_ne!(a, b);
        assert_ne!(b, c);
        // terminate the middle instance specifically
        let tid = ss.terminate(b, SimTime::from_secs(3610), &mut billing);
        assert_eq!(tid, Some(b));
        assert_eq!(ss.running_count(), 2);
        // 1 hour spot uptime booked for b only
        assert!((billing.total() - 0.076).abs() < 1e-9);
        // a is still the oldest running instance
        assert_eq!(ss.current().unwrap().id, a);
        // unknown id is a no-op
        assert!(ss.terminate(b, SimTime::from_secs(4000), &mut billing).is_none());
    }

    #[test]
    fn external_ids_keep_sequence_monotone() {
        let mut ss = mk().with_capacity(2);
        let mut billing = BillingMeter::new();
        ss.launch_with_id(InstanceId(7), SimTime::ZERO);
        // internal allocation resumes above the external id
        let id = ss.launch(SimTime::from_secs(1)).id;
        assert_eq!(id, InstanceId(8));
        ss.terminate(InstanceId(7), SimTime::from_secs(2), &mut billing);
        ss.terminate(InstanceId(8), SimTime::from_secs(2), &mut billing);
    }

    #[test]
    fn pool_label_attributes_billing() {
        let mut ss = mk().with_pool_label("east");
        let mut billing = BillingMeter::new();
        ss.launch(SimTime::ZERO);
        ss.terminate_current(SimTime::from_secs(3600), &mut billing);
        assert!((billing.pool_compute_total("east") - 0.076).abs() < 1e-9);
        assert_eq!(billing.pool_compute_total("west"), 0.0);
    }

    #[test]
    fn reclaim_unbilled_terminates_without_booking() {
        let mut ss = mk();
        ss.launch(SimTime::ZERO);
        let inst =
            ss.reclaim_current_unbilled(SimTime::from_secs(3600)).unwrap();
        assert_eq!(inst.id, InstanceId(0));
        assert!(!inst.is_running());
        assert_eq!(inst.uptime(SimTime::from_secs(9999)).as_secs(), 3600);
        assert!(ss.current().is_none());
        assert!(ss.reclaim_current_unbilled(SimTime::from_secs(3700)).is_none());
    }

    #[test]
    fn reclaim_unbilled_by_id_picks_the_right_instance() {
        let mut ss = mk().with_capacity(3);
        let a = ss.launch(SimTime::ZERO).id;
        let b = ss.launch(SimTime::from_secs(10)).id;
        let inst = ss.reclaim_unbilled(b, SimTime::from_secs(3610)).unwrap();
        assert_eq!(inst.id, b);
        assert_eq!(inst.uptime(SimTime::from_secs(9999)).as_secs(), 3600);
        assert_eq!(ss.running_count(), 1);
        assert_eq!(ss.current().unwrap().id, a);
        assert!(ss.reclaim_unbilled(b, SimTime::from_secs(3620)).is_none());
    }

    #[test]
    fn rejects_unknown_size() {
        assert!(ScaleSet::new(
            "Standard_Zeppelin",
            true,
            SimDuration::ZERO,
            PriceBook::default()
        )
        .is_err());
        let mut ss = mk();
        assert!(ss.resize("Standard_Zeppelin").is_err());
        assert!(ss.resize("Standard_D16s_v3").is_ok());
        assert_eq!(ss.vm_size(), "Standard_D16s_v3");
    }

    #[test]
    fn ondemand_billing_price() {
        let mut ss = ScaleSet::new(
            "Standard_D8s_v3",
            false,
            SimDuration::ZERO,
            PriceBook::default(),
        )
        .unwrap();
        let mut billing = BillingMeter::new();
        ss.launch(SimTime::ZERO);
        ss.terminate_current(SimTime::from_secs(3600), &mut billing);
        assert!((billing.total() - 0.38).abs() < 1e-9);
    }
}
