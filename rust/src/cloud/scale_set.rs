//! Scale set: the VM pool manager (Azure "Virtual Machine Scale Sets").
//!
//! The paper deploys workloads through scale sets because they "act as a
//! VM pool manager that is capable of restarting new spot instances upon
//! eviction of existing spot instances" (§III). This model keeps one
//! instance alive (capacity 1, like the paper's runs): when the current
//! instance is evicted, a replacement enters provisioning and comes up
//! after `provisioning_delay`. Custom Data (the coordinator launch script)
//! is re-run on every new instance — in this codebase that corresponds to
//! the restart path of [`crate::coordinator`].

use super::billing::BillingMeter;
use super::instance::{Instance, InstanceId};
use super::pricing::PriceBook;
use crate::simclock::{SimDuration, SimTime};
use anyhow::Result;

/// Capacity-1 scale set with automatic replacement.
#[derive(Debug)]
pub struct ScaleSet {
    vm_size: String,
    spot: bool,
    provisioning_delay: SimDuration,
    price_book: PriceBook,
    next_id: u64,
    current: Option<Instance>,
    /// Total instances launched over the experiment (for reporting).
    launched: u32,
}

impl ScaleSet {
    pub fn new(
        vm_size: &str,
        spot: bool,
        provisioning_delay: SimDuration,
        price_book: PriceBook,
    ) -> Result<Self> {
        // Validate the size exists up front.
        price_book.lookup(vm_size)?;
        Ok(Self {
            vm_size: vm_size.to_string(),
            spot,
            provisioning_delay,
            price_book,
            next_id: 0,
            current: None,
            launched: 0,
        })
    }

    /// Launch a new instance, immediately Running at `now`. (The
    /// provisioning delay is charged by the driver between the eviction
    /// and calling this — see [`Self::provisioning_delay`].)
    pub fn launch(&mut self, now: SimTime) -> &Instance {
        assert!(
            self.current.as_ref().map_or(true, |i| !i.is_running()),
            "scale set capacity is 1"
        );
        let id = InstanceId(self.next_id);
        self.next_id += 1;
        self.launched += 1;
        self.current = Some(Instance::new(id, &self.vm_size, self.spot, now));
        self.current.as_ref().unwrap()
    }

    /// The currently-live instance, if any.
    pub fn current(&self) -> Option<&Instance> {
        self.current.as_ref().filter(|i| i.is_running())
    }

    pub fn current_mut(&mut self) -> Option<&mut Instance> {
        self.current.as_mut().filter(|i| i.is_running())
    }

    /// Terminate the current instance at `now`, booking its uptime.
    pub fn terminate_current(
        &mut self,
        now: SimTime,
        billing: &mut BillingMeter,
    ) -> Option<InstanceId> {
        let inst = self.current.as_mut()?;
        if !inst.is_running() {
            return None;
        }
        let uptime = inst.terminate(now);
        let size = self
            .price_book
            .lookup(&inst.vm_size)
            .expect("validated at construction");
        billing.book_instance(
            &inst.id.to_string(),
            &inst.vm_size,
            inst.spot,
            uptime,
            size.price_per_hour(inst.spot),
        );
        Some(inst.id)
    }

    /// Delay before a replacement instance is Running.
    pub fn provisioning_delay(&self) -> SimDuration {
        self.provisioning_delay
    }

    /// The instant a launch requested at `now` is Running — the event the
    /// simulation engine schedules instead of blocking the clock. The
    /// first launch of a scale set is immediate (capacity was free);
    /// replacements pay the provisioning delay.
    pub fn replacement_ready_at(&self, now: SimTime) -> SimTime {
        if self.launched == 0 {
            now
        } else {
            now + self.provisioning_delay
        }
    }

    /// Change the VM size for future launches (OOM-resume upsizing,
    /// paper §IV).
    pub fn resize(&mut self, vm_size: &str) -> Result<()> {
        self.price_book.lookup(vm_size)?;
        self.vm_size = vm_size.to_string();
        Ok(())
    }

    pub fn vm_size(&self) -> &str {
        &self.vm_size
    }

    pub fn spot(&self) -> bool {
        self.spot
    }

    pub fn launched(&self) -> u32 {
        self.launched
    }

    pub fn price_book(&self) -> &PriceBook {
        &self.price_book
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk() -> ScaleSet {
        ScaleSet::new(
            "Standard_D8s_v3",
            true,
            SimDuration::from_secs(90),
            PriceBook::default(),
        )
        .unwrap()
    }

    #[test]
    fn launch_terminate_relaunch() {
        let mut ss = mk();
        let mut billing = BillingMeter::new();
        let id0 = ss.launch(SimTime::ZERO).id;
        assert!(ss.current().is_some());
        let tid = ss
            .terminate_current(SimTime::from_secs(3600), &mut billing)
            .unwrap();
        assert_eq!(tid, id0);
        assert!(ss.current().is_none());
        // one spot hour at $0.076
        assert!((billing.total() - 0.076).abs() < 1e-9);
        let id1 = ss.launch(SimTime::from_secs(3690)).id;
        assert_ne!(id0, id1);
        assert_eq!(ss.launched(), 2);
    }

    #[test]
    fn terminate_when_empty_is_none() {
        let mut ss = mk();
        let mut billing = BillingMeter::new();
        assert!(ss.terminate_current(SimTime::ZERO, &mut billing).is_none());
        assert_eq!(billing.total(), 0.0);
    }

    #[test]
    #[should_panic(expected = "capacity is 1")]
    fn capacity_is_one() {
        let mut ss = mk();
        ss.launch(SimTime::ZERO);
        ss.launch(SimTime::from_secs(1));
    }

    #[test]
    fn rejects_unknown_size() {
        assert!(ScaleSet::new(
            "Standard_Zeppelin",
            true,
            SimDuration::ZERO,
            PriceBook::default()
        )
        .is_err());
        let mut ss = mk();
        assert!(ss.resize("Standard_Zeppelin").is_err());
        assert!(ss.resize("Standard_D16s_v3").is_ok());
        assert_eq!(ss.vm_size(), "Standard_D16s_v3");
    }

    #[test]
    fn ondemand_billing_price() {
        let mut ss = ScaleSet::new(
            "Standard_D8s_v3",
            false,
            SimDuration::ZERO,
            PriceBook::default(),
        )
        .unwrap();
        let mut billing = BillingMeter::new();
        ss.launch(SimTime::ZERO);
        ss.terminate_current(SimTime::from_secs(3600), &mut billing);
        assert!((billing.total() - 0.38).abs() < 1e-9);
    }
}
