//! Multi-pool replacement fleets with pluggable placement.
//!
//! The paper runs every workload on a single capacity-1 scale set; this
//! module generalizes that into a [`Fleet`] of N pools — each a
//! [`ScaleSet`] with its own [`PriceBook`] (via a per-pool price factor),
//! its own [`EvictionPlan`], and its own provisioning delay — so a
//! replacement after an eviction can land in a *different* region /
//! VM-size pool with different price and eviction behaviour
//! (heterogeneous spot provisioning à la Qu et al. / Voorsluys & Buyya).
//!
//! Replacement is an event chain on the simulation engine, not a direct
//! call: `ReplacementRequested → PlacementDecided(pool) →
//! InstanceProvisioned` ([`crate::sim::engine::SimEvent`]). The pool is
//! picked by a [`PlacementPolicy`]:
//!
//! * [`StickyPool`] — replace in the pool the instance died in. On a
//!   1-pool fleet this reproduces the single-scale-set world
//!   byte-for-byte (the equivalence suite pins it against
//!   [`crate::sim::legacy`]).
//! * [`CheapestSpot`] — always the lowest hourly price.
//! * [`EvictionAware`] — minimize `price × (1 + penalty ×
//!   evictions/launches)`, steering away from pools observed to churn.
//!
//! The fleet keeps one experiment-wide instance-id sequence across its
//! pools and tags every booked uptime with the pool name, so
//! [`BillingMeter::pool_compute_total`] attributes the run's compute cost
//! pool by pool (the per-pool cost table in [`crate::report::fleet`]).
//!
//! Pools may carry a **price trace** ([`super::trace`]): the pool's
//! effective hourly price becomes `catalog × price_factor ×
//! trace_factor(t)`, replayed by the engine as `PoolPriceChanged` events
//! ([`Fleet::price_points`] → [`Fleet::apply_price_factor`]). Placement
//! policies see the moving price through [`PoolView::price_per_hour`]
//! and re-decide at every replacement, and a traced pool bills uptime
//! piecewise at its price-epoch boundaries
//! ([`BillingMeter::book_instance_piecewise`]), so an instance that
//! straddles a price move is invoiced per segment.

use super::billing::BillingMeter;
use super::eviction::EvictionPlan;
use super::instance::{Instance, InstanceId};
use super::pricing::PriceBook;
use super::scale_set::ScaleSet;
use super::trace::PricePoint;
use crate::config::{
    PlacementPolicyCfg, PoolCfg, PoolPricingCfg, ScenarioConfig,
};
use crate::simclock::{SimDuration, SimTime};
use anyhow::{bail, Result};
use std::fmt;

/// Index of a pool within its fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PoolId(pub usize);

impl fmt::Display for PoolId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "pool-{}", self.0)
    }
}

/// Read-only view of one pool, handed to placement policies.
#[derive(Debug, Clone)]
pub struct PoolView {
    pub id: PoolId,
    pub name: String,
    pub vm_size: String,
    pub spot: bool,
    /// Hourly price of this pool's VM size at the pool's price level.
    pub price_per_hour: f64,
    pub provisioning_delay: SimDuration,
    /// Instances launched into this pool so far.
    pub launched: u32,
    /// Evictions observed in this pool so far.
    pub evictions: u32,
    /// Does this pool's price move over time (trace or walk)? Bid
    /// policies only bid where the price can actually cross a bid.
    pub traced: bool,
    /// Static per-pool bid ($/h) every instance launched here carries,
    /// if the scenario configured one.
    pub bid: Option<f64>,
}

impl PoolView {
    /// Observed evictions per launch (0 for an untried pool — policies
    /// stay optimistic about pools they have no evidence against).
    pub fn eviction_rate(&self) -> f64 {
        self.evictions as f64 / self.launched.max(1) as f64
    }
}

/// Picks the pool for the next replacement. `active` is the pool the
/// dying (or initial) instance belongs to; `pools` always has ≥ 1 entry.
pub trait PlacementPolicy: fmt::Debug {
    fn name(&self) -> &'static str;
    fn place(&mut self, active: PoolId, pools: &[PoolView]) -> PoolId;
}

/// Replace in the same pool, always — the paper's single-scale-set
/// semantics generalized to "never move".
#[derive(Debug, Default)]
pub struct StickyPool;

impl PlacementPolicy for StickyPool {
    fn name(&self) -> &'static str {
        "sticky"
    }

    fn place(&mut self, active: PoolId, _pools: &[PoolView]) -> PoolId {
        active
    }
}

/// Always the lowest hourly price; ties go to the lowest pool index.
#[derive(Debug, Default)]
pub struct CheapestSpot;

impl PlacementPolicy for CheapestSpot {
    fn name(&self) -> &'static str {
        "cheapest-spot"
    }

    fn place(&mut self, _active: PoolId, pools: &[PoolView]) -> PoolId {
        let mut best = &pools[0];
        for p in &pools[1..] {
            if p.price_per_hour < best.price_per_hour {
                best = p;
            }
        }
        best.id
    }
}

/// Minimize `price × (1 + penalty × eviction_rate)`: price still matters,
/// but a pool that keeps evicting gets progressively more expensive in
/// the policy's eyes. Ties go to the lowest pool index.
#[derive(Debug)]
pub struct EvictionAware {
    pub penalty: f64,
}

impl EvictionAware {
    fn score(&self, p: &PoolView) -> f64 {
        p.price_per_hour * (1.0 + self.penalty * p.eviction_rate())
    }
}

impl PlacementPolicy for EvictionAware {
    fn name(&self) -> &'static str {
        "eviction-aware"
    }

    fn place(&mut self, _active: PoolId, pools: &[PoolView]) -> PoolId {
        let mut best = &pools[0];
        let mut best_score = self.score(best);
        for p in &pools[1..] {
            let s = self.score(p);
            if s < best_score {
                best = p;
                best_score = s;
            }
        }
        best.id
    }
}

/// Build the policy a config names. Rejects a non-finite or negative
/// `EvictionAware` penalty (mirroring `PriceBook::new`'s validation): a
/// NaN penalty makes every score NaN, so `place()` would silently
/// degrade to "always pool 0", and a negative one *rewards* churning
/// pools.
pub fn build_policy(cfg: &PlacementPolicyCfg) -> Result<Box<dyn PlacementPolicy>> {
    Ok(match cfg {
        PlacementPolicyCfg::Sticky => Box::new(StickyPool),
        PlacementPolicyCfg::CheapestSpot => Box::new(CheapestSpot),
        PlacementPolicyCfg::EvictionAware { penalty } => {
            if !(penalty.is_finite() && *penalty >= 0.0) {
                bail!(
                    "eviction-aware penalty {penalty} must be finite and \
                     non-negative"
                );
            }
            Box::new(EvictionAware { penalty: *penalty })
        }
    })
}

/// Per-pool outcome of a run, carried on
/// [`crate::sim::RunResult::pool_stats`].
#[derive(Debug, Clone, PartialEq)]
pub struct PoolStats {
    pub pool: String,
    pub vm_size: String,
    pub spot: bool,
    pub launches: u32,
    pub evictions: u32,
    /// Compute cost attributed to this pool's instances.
    pub compute_cost: f64,
}

/// One pool of the fleet: a scale set plus the pool's eviction plan,
/// observed-eviction counter, and (for traced spot markets) its price
/// history.
#[derive(Debug)]
struct Pool {
    name: String,
    set: ScaleSet,
    plan: EvictionPlan,
    evictions: u32,
    /// Does this pool's price move over time? Static pools keep the
    /// legacy single-price booking path bit-for-bit.
    traced: bool,
    /// Price-factor history: `(since, factor)`, time-ordered, seeded
    /// with `(t=0, initial factor)` at construction. Terminations bill
    /// uptime piecewise at these boundaries.
    price_epochs: Vec<(SimTime, f64)>,
    /// Trace points still to be replayed by the engine (offsets > 0).
    price_points: Vec<PricePoint>,
    /// Static bid carried by every instance launched in this pool
    /// (validated against the pool's pricing at construction).
    bid: Option<f64>,
}

impl Pool {
    /// Hourly price at the pool's *static* level (catalog ×
    /// `price_factor`) — what the trace factor multiplies.
    fn base_price(&self) -> f64 {
        self.set
            .price_book()
            .lookup(self.set.vm_size())
            // spoton-lint: allow(D3, reason = "pool set validated non-empty at construction")
            .expect("validated at construction")
            .price_per_hour(self.set.spot())
    }

    fn current_factor(&self) -> f64 {
        // spoton-lint: allow(D3, reason = "price_epochs seeded at construction; never emptied")
        self.price_epochs.last().expect("seeded at construction").1
    }

    /// Effective hourly price right now. Skips the multiply at factor
    /// 1.0 so untraced (and constant-1.0-traced) pools stay bit-identical
    /// to the pre-trace world.
    fn current_price(&self) -> f64 {
        let factor = self.current_factor();
        if factor == 1.0 {
            self.base_price()
        } else {
            self.base_price() * factor
        }
    }
}

/// N pools, one live-instance slot, one experiment-wide id sequence.
///
/// The fleet keeps the engine's capacity-1 workload model: at most one
/// instance runs the workload at a time, but each replacement may be
/// placed in any pool. (Multi-slot batch clusters get their fleet by
/// sharing a [`crate::config::FleetCfg`] across jobs — see
/// [`crate::sched`].)
#[derive(Debug)]
pub struct Fleet {
    pools: Vec<Pool>,
    /// Where the next launch goes (set by the placement decision).
    active: PoolId,
    /// Pool of the currently-live instance, if any.
    current_pool: Option<PoolId>,
    next_id: u64,
    total_launched: u32,
}

impl Fleet {
    /// Build a fleet from explicit pool configs. Pool 0's eviction plan
    /// draws from `seed` exactly as the pre-fleet single scale set did
    /// (1-pool fleets must replay the legacy world bit-for-bit); later
    /// pools decorrelate their plans with an index-keyed seed.
    pub fn new(pool_cfgs: &[PoolCfg], seed: u64) -> Result<Self> {
        if pool_cfgs.is_empty() {
            bail!("fleet needs at least one pool");
        }
        let mut pools = Vec::with_capacity(pool_cfgs.len());
        for (i, pc) in pool_cfgs.iter().enumerate() {
            if pools.iter().any(|p: &Pool| p.name == pc.name) {
                bail!("duplicate pool name '{}'", pc.name);
            }
            // Mirror the TOML-side check for builder-built configs: a
            // zero-capacity pool could never admit a job.
            if pc.capacity == 0 {
                bail!("pool '{}' capacity must be >= 1, got 0", pc.name);
            }
            let book = PriceBook::default().with_price_factor(pc.price_factor)?;
            let mut set = ScaleSet::new(
                &pc.vm_size,
                pc.spot,
                pc.provisioning_delay,
                book,
            )?
            .with_capacity(pc.capacity);
            // Pool tags exist for multi-pool attribution; a 1-pool fleet
            // books exactly like the pre-fleet scale set so legacy-world
            // invoices (and the equivalence oracle's) stay byte-identical.
            if pool_cfgs.len() > 1 {
                set = set.with_pool_label(&pc.name);
            }
            let pool_seed = if i == 0 {
                seed
            } else {
                seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            };
            // Expand the pool's price dynamics: a walk generates its
            // trace here (deterministic per pool seed), an explicit
            // trace is used as-is, and an offset-0 point becomes the
            // initial epoch instead of a scheduled t=0 event.
            let (traced, initial_factor, price_points) = match &pc.pricing {
                PoolPricingCfg::Static => (false, 1.0, Vec::new()),
                PoolPricingCfg::Trace(trace) => (
                    true,
                    trace.initial_factor(),
                    trace.scheduled_points().to_vec(),
                ),
                PoolPricingCfg::Walk(walk) => {
                    let trace = walk.generate(pool_seed).map_err(|e| {
                        e.context(format!("pool '{}' price walk", pc.name))
                    })?;
                    (
                        true,
                        trace.initial_factor(),
                        trace.scheduled_points().to_vec(),
                    )
                }
            };
            // Bid validation mirrors the TOML-side checks for
            // builder-built configs, plus the catalog-dependent rule the
            // parser cannot see: a bid below the pool's *initial*
            // effective price would leave the pool born outbid.
            if let Some(bid) = pc.bid {
                if !(bid.is_finite() && bid > 0.0) {
                    bail!(
                        "pool '{}': bid {bid} must be positive and finite",
                        pc.name
                    );
                }
                if !pc.spot {
                    bail!(
                        "pool '{}': bid requires a spot pool — on-demand \
                         instances are never outbid",
                        pc.name
                    );
                }
                if !traced {
                    bail!(
                        "pool '{}': bid is inert without traced or walked \
                         pricing — a static price can never cross it",
                        pc.name
                    );
                }
                let initial = set
                    .price_book()
                    .lookup(set.vm_size())?
                    .price_per_hour(set.spot())
                    * initial_factor;
                if bid < initial {
                    bail!(
                        "pool '{}': bid ${bid}/h is below the pool's initial \
                         effective price ${initial}/h — every instance would \
                         be born outbid",
                        pc.name
                    );
                }
            }
            pools.push(Pool {
                name: pc.name.clone(),
                set,
                plan: EvictionPlan::new(pc.eviction.clone(), pool_seed),
                evictions: 0,
                traced,
                price_epochs: vec![(SimTime::ZERO, initial_factor)],
                price_points,
                bid: pc.bid,
            });
        }
        Ok(Self {
            pools,
            active: PoolId(0),
            current_pool: None,
            next_id: 0,
            total_launched: 0,
        })
    }

    /// The fleet a scenario describes: its explicit `[pool.*]` sections,
    /// or — when none are given — the single pool the `[cloud]` +
    /// `[eviction]` sections define (the paper's testbed).
    pub fn from_scenario(cfg: &ScenarioConfig) -> Result<Self> {
        if cfg.fleet.pools.is_empty() {
            let mut pool = PoolCfg::from_cloud(&cfg.cloud, cfg.eviction.clone());
            // A `[cluster]` section may widen the implicit pool so many
            // jobs can run concurrently ([`crate::sim::cluster`]).
            if let Some(cap) = cfg.cluster.as_ref().and_then(|c| c.capacity) {
                pool.capacity = cap;
            }
            Self::new(&[pool], cfg.seed)
        } else {
            Self::new(&cfg.fleet.pools, cfg.seed)
        }
    }

    pub fn num_pools(&self) -> usize {
        self.pools.len()
    }

    pub fn is_multi_pool(&self) -> bool {
        self.pools.len() > 1
    }

    pub fn active_pool(&self) -> PoolId {
        self.active
    }

    pub fn pool_name(&self, pool: PoolId) -> &str {
        &self.pools[pool.0].name
    }

    /// Direct replacement target for the next launch (the engine's
    /// `PlacementDecided` handler).
    pub fn set_active(&mut self, pool: PoolId) -> Result<()> {
        if pool.0 >= self.pools.len() {
            bail!(
                "placement picked {pool} but the fleet has {} pool(s)",
                self.pools.len()
            );
        }
        self.active = pool;
        Ok(())
    }

    /// Policy-facing views of every pool. `price_per_hour` is the
    /// *current* price — for traced pools it moves as the engine replays
    /// price points, which is what lets [`CheapestSpot`] /
    /// [`EvictionAware`] re-decide as the market moves.
    pub fn views(&self) -> Vec<PoolView> {
        self.pools
            .iter()
            .enumerate()
            .map(|(i, p)| {
                let price = p.current_price();
                PoolView {
                    id: PoolId(i),
                    name: p.name.clone(),
                    vm_size: p.set.vm_size().to_string(),
                    spot: p.set.spot(),
                    price_per_hour: price,
                    provisioning_delay: p.set.provisioning_delay(),
                    launched: p.set.launched(),
                    evictions: p.evictions,
                    traced: p.traced,
                    bid: p.bid,
                }
            })
            .collect()
    }

    /// Launch an instance in the active pool, immediately Running at
    /// `now`. Ids are sequential fleet-wide, matching the single-scale-set
    /// sequence on a 1-pool fleet.
    pub fn launch(&mut self, now: SimTime) -> &Instance {
        let id = InstanceId(self.next_id);
        self.next_id += 1;
        self.total_launched += 1;
        self.current_pool = Some(self.active);
        self.pools[self.active.0].set.launch_with_id(id, now)
    }

    /// The currently-live instance, if any.
    pub fn current(&self) -> Option<&Instance> {
        self.pools[self.current_pool?.0].set.current()
    }

    /// Eviction-notice offset for the instance just launched, drawn from
    /// its pool's plan. Call once per launch, in launch order.
    pub fn next_eviction_offset(&mut self) -> Option<SimDuration> {
        let pool = self.current_pool.unwrap_or(self.active);
        self.pools[pool.0].plan.next_eviction_offset()
    }

    /// Terminate the live instance at `now`, booking its uptime against
    /// its pool. Returns the instance id and the pool it lived in.
    ///
    /// Static-priced pools book through the scale set exactly as before
    /// the trace layer (bit-identical invoices); traced pools bill
    /// piecewise at their price-epoch boundaries, so an instance that
    /// straddled a price move gets one line item per price segment.
    pub fn terminate_current(
        &mut self,
        now: SimTime,
        billing: &mut BillingMeter,
    ) -> Option<(InstanceId, PoolId)> {
        let pool = self.current_pool?;
        let multi = self.is_multi_pool();
        let p = &mut self.pools[pool.0];
        let id = if !p.traced {
            p.set.terminate_current(now, billing)?
        } else {
            let inst = p.set.reclaim_current_unbilled(now)?;
            // price the *instance's* size (it may differ from the set's
            // current size after an OOM-resume upsizing), exactly as
            // `ScaleSet::terminate` does on the static path
            let base = p
                .set
                .price_book()
                .lookup(&inst.vm_size)
                // spoton-lint: allow(D3, reason = "pool id validated when the launch was accepted")
                .expect("validated at launch")
                .price_per_hour(inst.spot);
            billing.book_instance_piecewise(
                if multi { Some(p.name.as_str()) } else { None },
                &inst.id.to_string(),
                &inst.vm_size,
                inst.spot,
                inst.started_at,
                now,
                base,
                &p.price_epochs,
            );
            inst.id
        };
        self.current_pool = None;
        Some((id, pool))
    }

    /// Terminate the live instance at `now` after a market outbid at
    /// `outbid_at`: the instance still occupies its slot until `now`
    /// (the notice window runs from the crossing), but billing stops at
    /// the crossing boundary — the provider reclaimed the capacity, so
    /// the notice window is not charged. Bid validation guarantees the
    /// pool is traced; the piecewise booking is segment-exact up to
    /// `outbid_at` (clamped to the instance start).
    pub fn terminate_current_outbid(
        &mut self,
        now: SimTime,
        outbid_at: SimTime,
        billing: &mut BillingMeter,
    ) -> Option<(InstanceId, PoolId)> {
        let pool = self.current_pool?;
        let multi = self.is_multi_pool();
        let p = &mut self.pools[pool.0];
        let inst = p.set.reclaim_current_unbilled(now)?;
        let base = p
            .set
            .price_book()
            .lookup(&inst.vm_size)
            // spoton-lint: allow(D3, reason = "pool id validated when the launch was accepted")
            .expect("validated at launch")
            .price_per_hour(inst.spot);
        billing.book_instance_piecewise(
            if multi { Some(p.name.as_str()) } else { None },
            &inst.id.to_string(),
            &inst.vm_size,
            inst.spot,
            inst.started_at,
            outbid_at.max(inst.started_at),
            base,
            &p.price_epochs,
        );
        self.current_pool = None;
        Some((inst.id, pool))
    }

    /// Record an observed eviction in `pool` (placement-policy evidence).
    pub fn note_eviction(&mut self, pool: PoolId) {
        self.pools[pool.0].evictions += 1;
    }

    /// The trace points the engine must replay for `pool` as
    /// `PoolPriceChanged` events (time-ordered, offsets > 0; empty for
    /// static pools).
    pub fn price_points(&self, pool: PoolId) -> &[PricePoint] {
        &self.pools[pool.0].price_points
    }

    /// Current traced price factor of `pool` (1.0 for static pools and
    /// before a traced pool's first move) — the market signal cost-aware
    /// interval controllers ([`crate::policy`]) read at each boundary.
    pub fn price_factor(&self, pool: PoolId) -> f64 {
        self.pools[pool.0].current_factor()
    }

    /// Apply a traced price move at `now`: the pool's effective price
    /// becomes `base × factor` from `now` on (a new billing epoch).
    /// Returns the (old, new) hourly price for the timeline.
    pub fn apply_price_factor(
        &mut self,
        pool: PoolId,
        factor: f64,
        now: SimTime,
    ) -> (f64, f64) {
        let p = &mut self.pools[pool.0];
        let old = p.current_price();
        p.price_epochs.push((now, factor));
        (old, p.current_price())
    }

    /// The static bid every instance launched in `pool` carries (`None`
    /// when the pool has no configured bid).
    pub fn pool_bid(&self, pool: PoolId) -> Option<f64> {
        self.pools[pool.0].bid
    }

    /// Current effective hourly price of `pool` (catalog ×
    /// `price_factor` × current trace factor) — what an outbid check
    /// compares a bid against.
    pub fn pool_price(&self, pool: PoolId) -> f64 {
        self.pools[pool.0].current_price()
    }

    /// `pool`'s *static-level* hourly price (catalog × `price_factor`,
    /// before any trace factor) — what percentile-of-trace bid policies
    /// multiply a factor quantile against.
    pub fn pool_base_price(&self, pool: PoolId) -> f64 {
        self.pools[pool.0].base_price()
    }

    /// Observed evictions per launch in `pool` (0 for an untried pool) —
    /// the evidence behind reliability-aware bid policies, same ratio as
    /// [`PoolView::eviction_rate`].
    pub fn pool_eviction_rate(&self, pool: PoolId) -> f64 {
        let p = &self.pools[pool.0];
        p.evictions as f64 / p.set.launched().max(1) as f64
    }

    /// Whether `pool` provisions spot capacity (an on-demand pool never
    /// evicts and bills the undiscounted catalog price).
    pub fn pool_is_spot(&self, pool: PoolId) -> bool {
        self.pools[pool.0].set.spot()
    }

    /// Whether `pool` carries a price trace (only traced spot pools
    /// have moving prices, and therefore meaningful bids).
    pub fn pool_traced(&self, pool: PoolId) -> bool {
        self.pools[pool.0].traced
    }

    /// Nearest-rank `q`-quantile of `pool`'s full traced factor stream
    /// (initial factor plus every scheduled point) — the signal behind
    /// percentile-of-trace bid policies ([`crate::autoscale`]). `q` must
    /// be in (0, 1]; a static pool's stream is the single factor 1.0.
    pub fn factor_quantile(&self, pool: PoolId, q: f64) -> f64 {
        debug_assert!(q > 0.0 && q <= 1.0, "quantile {q} out of (0, 1]");
        let p = &self.pools[pool.0];
        let mut factors: Vec<f64> =
            Vec::with_capacity(1 + p.price_points.len());
        factors.push(p.price_epochs[0].1);
        factors.extend(p.price_points.iter().map(|pt| pt.factor));
        // factors are validated positive and finite at trace parse, so
        // the comparison is total
        factors.sort_by(|a, b| {
            // spoton-lint: allow(D3, reason = "trace factors validated finite at parse")
            a.partial_cmp(b).expect("trace factors are finite")
        });
        let rank = ((q * factors.len() as f64).ceil() as usize)
            .clamp(1, factors.len());
        factors[rank - 1]
    }

    /// Splice seeded market shocks into every traced pool's remaining
    /// price stream ([`crate::sim::chaos`]): inside each `(start, end)`
    /// window the traced factor is multiplied by `factor`; at the window
    /// end the underlying trace resumes. Static pools are untouched, and
    /// windows never start at t = 0 (the initial epoch stays), so
    /// shock-free pools keep their digests byte for byte. Call before
    /// the engine schedules price points.
    pub fn splice_market_shocks(
        &mut self,
        windows: &[(SimDuration, SimDuration)],
        factor: f64,
    ) {
        if windows.is_empty() {
            return;
        }
        for p in &mut self.pools {
            if !p.traced {
                continue;
            }
            p.price_points = super::trace::splice_price_shocks(
                p.price_epochs[0].1,
                &p.price_points,
                windows,
                factor,
            );
        }
    }

    /// When a launch placed in `pool` at `now` is Running. The fleet's
    /// very first launch is immediate (capacity was free — same rule the
    /// single scale set applied); replacements pay the pool's
    /// provisioning delay.
    pub fn ready_at(&self, pool: PoolId, now: SimTime) -> SimTime {
        if self.total_launched == 0 {
            now
        } else {
            now + self.pools[pool.0].set.provisioning_delay()
        }
    }

    pub fn total_launched(&self) -> u32 {
        self.total_launched
    }

    /// Per-pool stats with compute cost attributed via the meter. A
    /// 1-pool fleet books untagged (legacy-identical invoices), so its
    /// single pool owns the whole compute total by construction.
    pub fn stats(&self, billing: &BillingMeter) -> Vec<PoolStats> {
        let multi = self.is_multi_pool();
        self.pools
            .iter()
            .map(|p| PoolStats {
                pool: p.name.clone(),
                vm_size: p.set.vm_size().to_string(),
                spot: p.set.spot(),
                launches: p.set.launched(),
                evictions: p.evictions,
                compute_cost: if multi {
                    billing.pool_compute_total(&p.name)
                } else {
                    billing.compute_total()
                },
            })
            .collect()
    }

    // --- cluster-engine accessors ---------------------------------------
    //
    // The multiplexed cluster engine ([`crate::sim::cluster`]) runs many
    // instances at once, so the single-slot `current_pool` bookkeeping
    // above does not apply: the cluster tracks its own instance-to-job
    // mapping and addresses instances by id.

    /// Launch an instance in `pool`, immediately Running at `now`
    /// (cluster path). Uses the same fleet-wide id sequence as
    /// [`Fleet::launch`] but leaves the single-slot state untouched.
    /// Panics if the pool is at capacity — admission control must gate
    /// launches ([`Fleet::pool_running`] vs [`Fleet::pool_capacity`]).
    pub fn launch_in(&mut self, pool: PoolId, now: SimTime) -> &Instance {
        let id = InstanceId(self.next_id);
        self.next_id += 1;
        self.total_launched += 1;
        self.pools[pool.0].set.launch_with_id(id, now)
    }

    /// Eviction-notice offset for the instance just launched in `pool`,
    /// drawn from that pool's plan (cluster path). Call once per launch,
    /// in launch order — the draw sequence is per pool, so a 1-pool
    /// single-job cluster replays [`Fleet::next_eviction_offset`]'s
    /// draws exactly.
    pub fn next_eviction_offset_in(
        &mut self,
        pool: PoolId,
    ) -> Option<SimDuration> {
        self.pools[pool.0].plan.next_eviction_offset()
    }

    /// Terminate instance `id` in `pool` at `now`, booking its uptime
    /// (cluster path — the by-id sibling of [`Fleet::terminate_current`]
    /// with the identical static/piecewise billing split). Returns
    /// `false` if no such instance runs there.
    pub fn terminate_in(
        &mut self,
        pool: PoolId,
        id: InstanceId,
        now: SimTime,
        billing: &mut BillingMeter,
    ) -> bool {
        let multi = self.is_multi_pool();
        let p = &mut self.pools[pool.0];
        if !p.traced {
            return p.set.terminate(id, now, billing).is_some();
        }
        let Some(inst) = p.set.reclaim_unbilled(id, now) else {
            return false;
        };
        let base = p
            .set
            .price_book()
            .lookup(&inst.vm_size)
            // spoton-lint: allow(D3, reason = "pool id validated when the launch was accepted")
            .expect("validated at launch")
            .price_per_hour(inst.spot);
        billing.book_instance_piecewise(
            if multi { Some(p.name.as_str()) } else { None },
            &inst.id.to_string(),
            &inst.vm_size,
            inst.spot,
            inst.started_at,
            now,
            base,
            &p.price_epochs,
        );
        true
    }

    /// Terminate instance `id` in `pool` after a market outbid at
    /// `outbid_at` (cluster path — the by-id sibling of
    /// [`Fleet::terminate_current_outbid`]): the slot frees at `now`,
    /// billing stops at the crossing boundary. Returns `false` if no
    /// such instance runs there.
    pub fn terminate_in_outbid(
        &mut self,
        pool: PoolId,
        id: InstanceId,
        now: SimTime,
        outbid_at: SimTime,
        billing: &mut BillingMeter,
    ) -> bool {
        let multi = self.is_multi_pool();
        let p = &mut self.pools[pool.0];
        let Some(inst) = p.set.reclaim_unbilled(id, now) else {
            return false;
        };
        let base = p
            .set
            .price_book()
            .lookup(&inst.vm_size)
            // spoton-lint: allow(D3, reason = "pool id validated when the launch was accepted")
            .expect("validated at launch")
            .price_per_hour(inst.spot);
        billing.book_instance_piecewise(
            if multi { Some(p.name.as_str()) } else { None },
            &inst.id.to_string(),
            &inst.vm_size,
            inst.spot,
            inst.started_at,
            outbid_at.max(inst.started_at),
            base,
            &p.price_epochs,
        );
        true
    }

    /// `pool`'s configured maximum number of concurrent instances.
    pub fn pool_capacity(&self, pool: PoolId) -> u32 {
        self.pools[pool.0].set.capacity()
    }

    /// Instances currently running in `pool`.
    pub fn pool_running(&self, pool: PoolId) -> u32 {
        self.pools[pool.0].set.running_count() as u32
    }

    /// `pool`'s provisioning delay. The cluster engine applies the
    /// "first launch free" rule *per job* rather than fleet-wide, so it
    /// needs the raw delay instead of [`Fleet::ready_at`].
    pub fn pool_provisioning_delay(&self, pool: PoolId) -> SimDuration {
        self.pools[pool.0].set.provisioning_delay()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::trace::{PriceTrace, PriceWalkCfg};
    use crate::config::EvictionPlanCfg;

    fn three_pools() -> Vec<PoolCfg> {
        vec![
            PoolCfg::named("east").price_factor(0.85).eviction(
                EvictionPlanCfg::Fixed { interval: SimDuration::from_mins(5) },
            ),
            PoolCfg::named("west").price_factor(1.2),
            PoolCfg::named("south").price_factor(1.0),
        ]
    }

    #[test]
    fn fleet_launches_with_global_id_sequence() {
        let mut fleet = Fleet::new(&three_pools(), 7).unwrap();
        let mut billing = BillingMeter::new();
        assert_eq!(fleet.num_pools(), 3);
        assert!(fleet.is_multi_pool());

        // first launch in pool 0 is immediate
        assert_eq!(fleet.ready_at(PoolId(0), SimTime::ZERO), SimTime::ZERO);
        let id0 = fleet.launch(SimTime::ZERO).id;
        assert_eq!(id0, InstanceId(0));
        assert!(fleet.current().is_some());

        let (tid, pool) = fleet
            .terminate_current(SimTime::from_secs(3600), &mut billing)
            .unwrap();
        assert_eq!(tid, id0);
        assert_eq!(pool, PoolId(0));
        fleet.note_eviction(pool);
        assert!(fleet.current().is_none());

        // replacement into a different pool continues the id sequence
        fleet.set_active(PoolId(2)).unwrap();
        let ready = fleet.ready_at(PoolId(2), SimTime::from_secs(3600));
        assert!(ready > SimTime::from_secs(3600), "replacement pays delay");
        let id1 = fleet.launch(ready).id;
        assert_eq!(id1, InstanceId(1));

        let views = fleet.views();
        assert_eq!(views[0].launched, 1);
        assert_eq!(views[0].evictions, 1);
        assert!((views[0].eviction_rate() - 1.0).abs() < 1e-12);
        assert_eq!(views[2].launched, 1);
        assert_eq!(views[2].evictions, 0);
        // east is 0.85 × $0.076
        assert!((views[0].price_per_hour - 0.0646).abs() < 1e-9);
    }

    #[test]
    fn fleet_validates_configs() {
        assert!(Fleet::new(&[], 1).is_err());
        let dup = vec![PoolCfg::named("a"), PoolCfg::named("a")];
        assert!(Fleet::new(&dup, 1).is_err());
        let bad_size = vec![PoolCfg::named("a").vm_size("Standard_Zeppelin")];
        assert!(Fleet::new(&bad_size, 1).is_err());
        let bad_factor = vec![PoolCfg::named("a").price_factor(-1.0)];
        assert!(Fleet::new(&bad_factor, 1).is_err());
        let zero_cap = vec![PoolCfg::named("tiny").capacity(0)];
        let err = Fleet::new(&zero_cap, 1).unwrap_err();
        assert!(err.to_string().contains("'tiny'"), "{err}");
        assert!(err.to_string().contains("capacity"), "{err}");
        let mut fleet = Fleet::new(&three_pools(), 1).unwrap();
        assert!(fleet.set_active(PoolId(3)).is_err());
    }

    #[test]
    fn sticky_stays_cheapest_moves() {
        let fleet = Fleet::new(&three_pools(), 7).unwrap();
        let views = fleet.views();

        let mut sticky = StickyPool;
        assert_eq!(sticky.place(PoolId(1), &views), PoolId(1));

        let mut cheapest = CheapestSpot;
        // east (0.85×) is the cheapest
        assert_eq!(cheapest.place(PoolId(1), &views), PoolId(0));
    }

    #[test]
    fn eviction_aware_abandons_churning_pools() {
        let mut fleet = Fleet::new(&three_pools(), 7).unwrap();
        let mut policy = EvictionAware { penalty: 4.0 };

        // no evidence yet: price decides — east
        assert_eq!(policy.place(PoolId(0), &fleet.views()), PoolId(0));

        // east churns: launch + evict
        let mut billing = BillingMeter::new();
        fleet.launch(SimTime::ZERO);
        let (_, pool) = fleet
            .terminate_current(SimTime::from_secs(60), &mut billing)
            .unwrap();
        fleet.note_eviction(pool);

        // east now scores 0.0646 × 5 = 0.323; south (0.076) wins
        assert_eq!(policy.place(PoolId(0), &fleet.views()), PoolId(2));
    }

    #[test]
    fn placement_ties_go_to_the_lowest_pool_index() {
        // Regression pin for the documented tie rule: with equal prices
        // (and equal eviction evidence) every price-driven policy must
        // return pool 0 — a refactor that flips iteration order or
        // switches `<` to `<=` would silently reorder sweep winners.
        let cfgs =
            vec![PoolCfg::named("a"), PoolCfg::named("b"), PoolCfg::named("c")];
        let mut fleet = Fleet::new(&cfgs, 1).unwrap();
        let views = fleet.views();
        assert!(views
            .windows(2)
            .all(|w| w[0].price_per_hour == w[1].price_per_hour));

        let mut cheapest = CheapestSpot;
        assert_eq!(cheapest.place(PoolId(2), &views), PoolId(0));
        let mut aware = EvictionAware { penalty: 4.0 };
        assert_eq!(aware.place(PoolId(2), &views), PoolId(0));

        // identical nonzero evidence everywhere still ties → pool 0
        let mut billing = BillingMeter::new();
        for i in 0..3 {
            fleet.set_active(PoolId(i)).unwrap();
            fleet.launch(SimTime::from_secs(i as u64 * 100));
            let (_, pool) = fleet
                .terminate_current(
                    SimTime::from_secs(i as u64 * 100 + 50),
                    &mut billing,
                )
                .unwrap();
            fleet.note_eviction(pool);
        }
        let views = fleet.views();
        assert!(views.iter().all(|v| v.launched == 1 && v.evictions == 1));
        assert_eq!(aware.place(PoolId(2), &views), PoolId(0));
        assert_eq!(cheapest.place(PoolId(1), &views), PoolId(0));
    }

    #[test]
    fn build_policy_rejects_invalid_penalties() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -1.0] {
            let err =
                build_policy(&PlacementPolicyCfg::EvictionAware { penalty: bad })
                    .unwrap_err();
            assert!(err.to_string().contains("penalty"), "{bad}: {err}");
        }
        assert!(build_policy(&PlacementPolicyCfg::EvictionAware {
            penalty: 0.0
        })
        .is_ok());
        assert!(build_policy(&PlacementPolicyCfg::Sticky).is_ok());
        assert!(build_policy(&PlacementPolicyCfg::CheapestSpot).is_ok());
    }

    #[test]
    fn traced_pool_price_moves_and_bills_piecewise() {
        let trace = PriceTrace::new(vec![
            PricePoint { offset: SimDuration::ZERO, factor: 1.0 },
            PricePoint { offset: SimDuration::from_mins(30), factor: 2.0 },
        ])
        .unwrap();
        let cfgs = vec![
            PoolCfg::named("traced")
                .pricing(PoolPricingCfg::Trace(trace.clone())),
            PoolCfg::named("static"),
        ];
        let mut fleet = Fleet::new(&cfgs, 7).unwrap();
        assert_eq!(fleet.price_points(PoolId(0)).len(), 1);
        assert!(fleet.price_points(PoolId(1)).is_empty());

        // launch in the traced pool, price doubles mid-uptime
        let mut billing = BillingMeter::new();
        fleet.launch(SimTime::ZERO);
        let d8_spot = 0.076;
        assert_eq!(fleet.views()[0].price_per_hour, d8_spot);
        let (old, new) = fleet.apply_price_factor(
            PoolId(0),
            2.0,
            SimTime::from_secs(1800),
        );
        assert_eq!(old, d8_spot);
        assert!((new - 0.152).abs() < 1e-12);
        assert_eq!(fleet.views()[0].price_per_hour, new);
        // the raw factor is exposed for cost-aware interval controllers
        assert_eq!(fleet.price_factor(PoolId(0)), 2.0);
        assert_eq!(fleet.price_factor(PoolId(1)), 1.0);

        // terminate after 1 h: 0.5 h at $0.076 + 0.5 h at $0.152
        fleet
            .terminate_current(SimTime::from_secs(3600), &mut billing)
            .unwrap();
        let inv = billing.invoice();
        assert_eq!(inv.items.len(), 2, "{inv}");
        assert!((billing.compute_total() - 0.5 * (0.076 + 0.152)).abs() < 1e-12);
        assert!(
            (billing.pool_compute_total("traced") - billing.compute_total())
                .abs()
                < 1e-12
        );
        let stats = fleet.stats(&billing);
        assert!((stats[0].compute_cost - billing.compute_total()).abs() < 1e-12);
        assert_eq!(stats[1].compute_cost, 0.0);
    }

    #[test]
    fn walk_priced_pools_are_deterministic_per_seed() {
        let cfgs = vec![PoolCfg::named("walker")
            .pricing(PoolPricingCfg::Walk(PriceWalkCfg::default()))];
        let a = Fleet::new(&cfgs, 99).unwrap();
        let b = Fleet::new(&cfgs, 99).unwrap();
        assert_eq!(a.price_points(PoolId(0)), b.price_points(PoolId(0)));
        assert!(!a.price_points(PoolId(0)).is_empty());
        let c = Fleet::new(&cfgs, 100).unwrap();
        assert_ne!(a.price_points(PoolId(0)), c.price_points(PoolId(0)));
        // an invalid walk is rejected at fleet construction
        let bad = vec![PoolCfg::named("w").pricing(PoolPricingCfg::Walk(
            PriceWalkCfg { start: -1.0, ..PriceWalkCfg::default() },
        ))];
        assert!(Fleet::new(&bad, 1).is_err());
    }

    #[test]
    fn single_pool_fleet_mirrors_scale_set_rules() {
        let cfg = ScenarioConfig::default();
        let mut fleet = Fleet::from_scenario(&cfg).unwrap();
        assert_eq!(fleet.num_pools(), 1);
        assert!(!fleet.is_multi_pool());
        assert_eq!(fleet.pool_name(PoolId(0)), "pool-0");
        // first launch free, replacement pays the cloud cfg delay
        assert_eq!(fleet.ready_at(PoolId(0), SimTime::ZERO), SimTime::ZERO);
        fleet.launch(SimTime::ZERO);
        let t = SimTime::from_secs(100);
        assert_eq!(
            fleet.ready_at(PoolId(0), t),
            t + cfg.cloud.provisioning_delay
        );
        // default scenario has no evictions
        assert_eq!(fleet.next_eviction_offset(), None);
    }

    #[test]
    fn cluster_accessors_run_many_instances_per_pool() {
        let cfgs = vec![
            PoolCfg::named("wide").capacity(3),
            PoolCfg::named("narrow"),
        ];
        let mut fleet = Fleet::new(&cfgs, 7).unwrap();
        assert_eq!(fleet.pool_capacity(PoolId(0)), 3);
        assert_eq!(fleet.pool_capacity(PoolId(1)), 1);
        assert_eq!(
            fleet.pool_provisioning_delay(PoolId(0)),
            PoolCfg::named("wide").provisioning_delay
        );

        let a = fleet.launch_in(PoolId(0), SimTime::ZERO).id;
        let b = fleet.launch_in(PoolId(0), SimTime::ZERO).id;
        let c = fleet.launch_in(PoolId(1), SimTime::ZERO).id;
        // one fleet-wide id sequence, shared with the single-slot path
        assert_eq!((a, b, c), (InstanceId(0), InstanceId(1), InstanceId(2)));
        assert_eq!(fleet.pool_running(PoolId(0)), 2);
        assert_eq!(fleet.pool_running(PoolId(1)), 1);
        assert_eq!(fleet.total_launched(), 3);
        // the single-slot view stays untouched
        assert!(fleet.current().is_none());

        // terminate out of launch order, by id
        let mut billing = BillingMeter::new();
        assert!(fleet.terminate_in(
            PoolId(0),
            b,
            SimTime::from_secs(3600),
            &mut billing
        ));
        assert_eq!(fleet.pool_running(PoolId(0)), 1);
        assert!(
            !fleet.terminate_in(
                PoolId(0),
                b,
                SimTime::from_secs(3600),
                &mut billing
            ),
            "double termination must report false"
        );
        // wrong pool: instance `a` lives in pool 0
        assert!(!fleet.terminate_in(
            PoolId(1),
            a,
            SimTime::from_secs(3600),
            &mut billing
        ));
        // one hour of d8 spot, attributed to the wide pool
        assert!((billing.compute_total() - 0.076).abs() < 1e-9);
        assert!(
            (billing.pool_compute_total("wide") - 0.076).abs() < 1e-9,
            "multi-pool cluster terminations tag the pool"
        );
    }

    #[test]
    fn cluster_terminate_in_bills_traced_pools_piecewise() {
        let trace = PriceTrace::new(vec![
            PricePoint { offset: SimDuration::ZERO, factor: 1.0 },
            PricePoint { offset: SimDuration::from_mins(30), factor: 2.0 },
        ])
        .unwrap();
        let cfgs = vec![PoolCfg::named("traced")
            .capacity(2)
            .pricing(PoolPricingCfg::Trace(trace))];
        let mut fleet = Fleet::new(&cfgs, 7).unwrap();
        let id = fleet.launch_in(PoolId(0), SimTime::ZERO).id;
        fleet.apply_price_factor(PoolId(0), 2.0, SimTime::from_secs(1800));
        let mut billing = BillingMeter::new();
        assert!(fleet.terminate_in(
            PoolId(0),
            id,
            SimTime::from_secs(3600),
            &mut billing
        ));
        // 0.5 h at $0.076 + 0.5 h at $0.152, as on the single-slot path
        assert!((billing.compute_total() - 0.5 * (0.076 + 0.152)).abs() < 1e-12);
        assert_eq!(billing.invoice().items.len(), 2);
    }

    #[test]
    fn fleet_validates_bids() {
        let spike = PriceTrace::new(vec![
            PricePoint { offset: SimDuration::ZERO, factor: 1.0 },
            PricePoint { offset: SimDuration::from_mins(30), factor: 2.0 },
        ])
        .unwrap();
        let traced = |bid: f64| {
            PoolCfg::named("p")
                .pricing(PoolPricingCfg::Trace(spike.clone()))
                .bid(bid)
        };

        for bad in [0.0, -0.05, f64::NAN, f64::INFINITY] {
            let err = Fleet::new(&[traced(bad)], 1).unwrap_err();
            assert!(
                err.to_string().contains("positive and finite"),
                "{bad}: {err}"
            );
        }
        // bids only mean something where an auction can be lost
        let err = Fleet::new(&[traced(0.10).spot(false)], 1).unwrap_err();
        assert!(err.to_string().contains("spot pool"), "{err}");
        let err =
            Fleet::new(&[PoolCfg::named("p").bid(0.10)], 1).unwrap_err();
        assert!(err.to_string().contains("inert"), "{err}");
        // a bid below the initial effective price is born outbid
        let opens_high = PriceTrace::new(vec![PricePoint {
            offset: SimDuration::ZERO,
            factor: 2.0,
        }])
        .unwrap();
        let err = Fleet::new(
            &[PoolCfg::named("p")
                .pricing(PoolPricingCfg::Trace(opens_high))
                .bid(0.10)],
            1,
        )
        .unwrap_err();
        assert!(err.to_string().contains("born outbid"), "{err}");
        // a viable bid round-trips through the accessors
        let fleet =
            Fleet::new(&[traced(0.10), PoolCfg::named("static")], 1).unwrap();
        assert_eq!(fleet.pool_bid(PoolId(0)), Some(0.10));
        assert_eq!(fleet.pool_bid(PoolId(1)), None);
        assert!(fleet.pool_traced(PoolId(0)));
        assert!(!fleet.pool_traced(PoolId(1)));
        assert!(fleet.pool_is_spot(PoolId(0)));
    }

    #[test]
    fn factor_quantile_is_nearest_rank_over_the_full_stream() {
        let trace = PriceTrace::new(vec![
            PricePoint { offset: SimDuration::ZERO, factor: 1.0 },
            PricePoint { offset: SimDuration::from_mins(10), factor: 0.8 },
            PricePoint { offset: SimDuration::from_mins(20), factor: 1.5 },
            PricePoint { offset: SimDuration::from_mins(30), factor: 2.0 },
        ])
        .unwrap();
        let fleet = Fleet::new(
            &[
                PoolCfg::named("traced")
                    .pricing(PoolPricingCfg::Trace(trace)),
                PoolCfg::named("static"),
            ],
            1,
        )
        .unwrap();
        // sorted stream: [0.8, 1.0, 1.5, 2.0] — nearest rank, 1-indexed
        assert_eq!(fleet.factor_quantile(PoolId(0), 0.01), 0.8);
        assert_eq!(fleet.factor_quantile(PoolId(0), 0.25), 0.8);
        assert_eq!(fleet.factor_quantile(PoolId(0), 0.5), 1.0);
        assert_eq!(fleet.factor_quantile(PoolId(0), 0.75), 1.5);
        assert_eq!(fleet.factor_quantile(PoolId(0), 1.0), 2.0);
        // a static pool's stream is the single factor 1.0
        assert_eq!(fleet.factor_quantile(PoolId(1), 0.25), 1.0);
        assert_eq!(fleet.factor_quantile(PoolId(1), 1.0), 1.0);
    }

    #[test]
    fn outbid_termination_stops_billing_at_the_crossing() {
        let trace = PriceTrace::new(vec![
            PricePoint { offset: SimDuration::ZERO, factor: 1.0 },
            PricePoint { offset: SimDuration::from_mins(30), factor: 2.0 },
        ])
        .unwrap();
        let cfgs = vec![PoolCfg::named("traced")
            .capacity(2)
            .pricing(PoolPricingCfg::Trace(trace))
            .bid(0.09)];
        // single-slot path: outbid at 45 min, slot reclaimed at 60 min —
        // only [0, 45 min) bills: 0.5 h at $0.076 + 0.25 h at $0.152
        let mut fleet = Fleet::new(&cfgs, 7).unwrap();
        let mut billing = BillingMeter::new();
        fleet.launch(SimTime::ZERO);
        fleet.apply_price_factor(PoolId(0), 2.0, SimTime::from_secs(1800));
        let (id, pool) = fleet
            .terminate_current_outbid(
                SimTime::from_secs(3600),
                SimTime::from_secs(2700),
                &mut billing,
            )
            .unwrap();
        assert_eq!((id, pool), (InstanceId(0), PoolId(0)));
        assert!(fleet.current().is_none());
        let billed = 0.5 * 0.076 + 0.25 * 0.152;
        assert!((billing.compute_total() - billed).abs() < 1e-12);

        // cluster path bills the identical window by id
        let mut fleet = Fleet::new(&cfgs, 7).unwrap();
        let mut by_id = BillingMeter::new();
        let id = fleet.launch_in(PoolId(0), SimTime::ZERO).id;
        fleet.apply_price_factor(PoolId(0), 2.0, SimTime::from_secs(1800));
        assert!(fleet.terminate_in_outbid(
            PoolId(0),
            id,
            SimTime::from_secs(3600),
            SimTime::from_secs(2700),
            &mut by_id
        ));
        assert_eq!(
            by_id.compute_total().to_bits(),
            billing.compute_total().to_bits()
        );
        assert!(
            !fleet.terminate_in_outbid(
                PoolId(0),
                id,
                SimTime::from_secs(3700),
                SimTime::from_secs(2700),
                &mut by_id
            ),
            "double outbid termination must report false"
        );

        // a crossing before launch clamps to the instance start: zero bill
        let mut fleet = Fleet::new(&cfgs, 7).unwrap();
        let mut zero = BillingMeter::new();
        let id = fleet.launch_in(PoolId(0), SimTime::from_secs(1000)).id;
        assert!(fleet.terminate_in_outbid(
            PoolId(0),
            id,
            SimTime::from_secs(2000),
            SimTime::from_secs(500),
            &mut zero
        ));
        assert_eq!(zero.compute_total(), 0.0);
    }

    #[test]
    fn prop_outbid_billing_equals_plain_termination_at_the_crossing() {
        // Metamorphic pin for the outbid billing clamp: terminating an
        // instance outbid at `t_x` (slot reclaimed later, at `now`) books
        // bitwise what a plain termination at `max(t_x, started_at)`
        // books — across random price-move histories and random
        // launch/crossing/reclaim orderings.
        use crate::util::proptest::{forall, shrink_none, Config};
        forall(
            Config::default().cases(120),
            |rng| {
                let n = rng.range_u64(0, 4);
                let mut moves = Vec::new();
                let mut t = 0u64;
                for _ in 0..n {
                    t += rng.range_u64(60, 3_000);
                    moves.push((SimTime(t), 0.5 + rng.f64()));
                }
                let started = SimTime(rng.below(4_000));
                let outbid = SimTime(rng.below(8_000));
                let now = started.max(outbid) + SimDuration::from_secs(
                    rng.range_u64(1, 600),
                );
                (moves, started, outbid, now)
            },
            shrink_none,
            |(moves, started, outbid, now)| {
                // a constant-1.0 trace marks the pool traced, so both
                // termination paths take the piecewise-billing branch
                let flat = PriceTrace::new(vec![PricePoint {
                    offset: SimDuration::ZERO,
                    factor: 1.0,
                }])
                .unwrap();
                let cfgs = vec![PoolCfg::named("p")
                    .capacity(2)
                    .pricing(PoolPricingCfg::Trace(flat))];
                let mut run = |as_outbid: bool| -> u64 {
                    let mut fleet = Fleet::new(&cfgs, 1).unwrap();
                    for &(t, f) in moves {
                        fleet.apply_price_factor(PoolId(0), f, t);
                    }
                    let id = fleet.launch_in(PoolId(0), *started).id;
                    let mut billing = BillingMeter::new();
                    let ok = if as_outbid {
                        fleet.terminate_in_outbid(
                            PoolId(0),
                            id,
                            *now,
                            *outbid,
                            &mut billing,
                        )
                    } else {
                        fleet.terminate_in(
                            PoolId(0),
                            id,
                            (*outbid).max(*started),
                            &mut billing,
                        )
                    };
                    assert!(ok);
                    billing.compute_total().to_bits()
                };
                let (got, want) = (run(true), run(false));
                if got != want {
                    return Err(format!(
                        "outbid bill {} != clamped plain bill {}",
                        f64::from_bits(got),
                        f64::from_bits(want)
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn market_shocks_splice_only_traced_pools() {
        let trace = PriceTrace::new(vec![
            PricePoint { offset: SimDuration::ZERO, factor: 1.0 },
            PricePoint { offset: SimDuration::from_mins(30), factor: 2.0 },
        ])
        .unwrap();
        let cfgs = vec![
            PoolCfg::named("traced").pricing(PoolPricingCfg::Trace(trace)),
            PoolCfg::named("static"),
        ];
        let mut fleet = Fleet::new(&cfgs, 7).unwrap();
        // no windows: a byte-level no-op
        let before = fleet.price_points(PoolId(0)).to_vec();
        fleet.splice_market_shocks(&[], 2.0);
        assert_eq!(fleet.price_points(PoolId(0)), &before[..]);

        // one 2.0× window at [10 min, 20 min): shock on, shock off, and
        // the underlying 30-min move all survive as change points
        fleet.splice_market_shocks(
            &[(SimDuration::from_mins(10), SimDuration::from_mins(20))],
            2.0,
        );
        assert_eq!(
            fleet.price_points(PoolId(0)),
            &[
                PricePoint { offset: SimDuration::from_mins(10), factor: 2.0 },
                PricePoint { offset: SimDuration::from_mins(20), factor: 1.0 },
                PricePoint { offset: SimDuration::from_mins(30), factor: 2.0 },
            ][..]
        );
        // the static pool never gains points
        assert!(fleet.price_points(PoolId(1)).is_empty());
    }

    #[test]
    fn pool_stats_attribute_costs() {
        let mut fleet = Fleet::new(&three_pools(), 7).unwrap();
        let mut billing = BillingMeter::new();
        fleet.launch(SimTime::ZERO);
        fleet
            .terminate_current(SimTime::from_secs(3600), &mut billing)
            .unwrap();
        fleet.set_active(PoolId(1)).unwrap();
        fleet.launch(SimTime::from_secs(3700));
        fleet
            .terminate_current(SimTime::from_secs(7300), &mut billing)
            .unwrap();
        let stats = fleet.stats(&billing);
        assert_eq!(stats.len(), 3);
        assert!((stats[0].compute_cost - 0.0646).abs() < 1e-9);
        assert!((stats[1].compute_cost - 0.0912).abs() < 1e-9);
        assert_eq!(stats[2].compute_cost, 0.0);
        let total: f64 = stats.iter().map(|s| s.compute_cost).sum();
        assert!((total - billing.compute_total()).abs() < 1e-12);
    }
}
