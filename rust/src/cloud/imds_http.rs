//! IMDS scheduled-events HTTP facade: the real-wire version of
//! [`super::metadata::MetadataService`].
//!
//! Azure serves scheduled events at
//! `http://169.254.169.254/metadata/scheduledevents?api-version=...` with
//! the mandatory `Metadata: true` header; inside the VM that address is
//! non-routable. The facade binds the same document/ack protocol to
//! `127.0.0.1:<port>` so real-time-mode integration tests drive the
//! coordinator's monitor over an actual TCP round-trip:
//!
//! * `GET  /metadata/scheduledevents?api-version=2020-07-01` → document
//! * `POST /metadata/scheduledevents?api-version=2020-07-01` → StartRequests
//! * `POST /admin/simulate-eviction?resource=<vm>` → inject a Preempt
//!   (the `az vmss simulate-eviction` analog; admin-only, not part of IMDS)
//!
//! Virtual-vs-real time: the HTTP facade stamps `NotBefore` from a shared
//! wall-clock epoch so notices still mean "N seconds from now".

use super::metadata::MetadataService;
use crate::httpd::{HttpServer, Request, Response};
use crate::json;
use crate::simclock::SimTime;
use anyhow::Result;
use std::sync::{Arc, Mutex};
use std::time::Instant;

pub const API_VERSION: &str = "2020-07-01";
pub const EVENTS_PATH: &str = "/metadata/scheduledevents";
pub const SIMULATE_PATH: &str = "/admin/simulate-eviction";

/// Shared state behind the HTTP endpoint.
pub struct ImdsState {
    pub service: MetadataService,
    epoch: Instant,
    /// Notice duration for injected evictions (Azure: >= 30 s).
    pub notice_secs: u64,
}

impl ImdsState {
    /// Wall-clock "now" as a SimTime offset from the server epoch, so the
    /// HTTP facade and in-proc service share one time representation.
    pub fn now(&self) -> SimTime {
        SimTime(self.epoch.elapsed().as_millis() as u64)
    }
}

/// A running IMDS HTTP endpoint.
pub struct ImdsHttp {
    server: HttpServer,
    state: Arc<Mutex<ImdsState>>,
}

impl ImdsHttp {
    pub fn spawn(notice_secs: u64) -> Result<Self> {
        let state = Arc::new(Mutex::new(ImdsState {
            service: MetadataService::new(),
            epoch: Instant::now(),
            notice_secs,
        }));
        let state2 = state.clone();
        let server = HttpServer::spawn(Arc::new(move |req: &Request| {
            handle(&state2, req)
        }))?;
        Ok(Self { server, state })
    }

    pub fn base_url(&self) -> String {
        self.server.base_url()
    }

    /// URL the coordinator's monitor polls.
    pub fn events_url(&self) -> String {
        format!(
            "{}{}?api-version={}",
            self.server.base_url(),
            EVENTS_PATH,
            API_VERSION
        )
    }

    pub fn state(&self) -> &Arc<Mutex<ImdsState>> {
        &self.state
    }

    pub fn shutdown(&mut self) {
        self.server.shutdown();
    }
}

fn handle(state: &Arc<Mutex<ImdsState>>, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", EVENTS_PATH) => {
            // Azure rejects requests without the Metadata header and with
            // a missing/unknown api-version.
            if req.header("metadata") != Some("true") {
                return Response::bad_request("Metadata: true header required");
            }
            if req.query_param("api-version") != Some(API_VERSION) {
                return Response::bad_request("unsupported api-version");
            }
            // spoton-lint: allow(D3, reason = "lock poisoning means a panicked holder; unrecoverable by design")
            let st = state.lock().unwrap();
            Response::ok_json(json::to_string(&st.service.document()))
        }
        ("POST", EVENTS_PATH) => {
            let body = match std::str::from_utf8(&req.body)
                .ok()
                .and_then(|s| json::parse(s).ok())
            {
                Some(v) => v,
                None => return Response::bad_request("invalid JSON body"),
            };
            // spoton-lint: allow(D3, reason = "lock poisoning means a panicked holder; unrecoverable by design")
            let mut st = state.lock().unwrap();
            let n = st.service.start_requests(&body);
            Response::ok_json(format!("{{\"acknowledged\":{n}}}"))
        }
        ("POST", SIMULATE_PATH) => {
            let resource = match req.query_param("resource") {
                Some(r) if !r.is_empty() => r.to_string(),
                _ => return Response::bad_request("resource param required"),
            };
            // spoton-lint: allow(D3, reason = "lock poisoning means a panicked holder; unrecoverable by design")
            let mut st = state.lock().unwrap();
            let not_before = st.now()
                + crate::simclock::SimDuration::from_secs(st.notice_secs);
            let id = st.service.post_preempt(&resource, not_before);
            Response::ok_json(format!("{{\"eventId\":\"{id}\"}}"))
        }
        _ => Response::not_found(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::metadata::parse_document;
    use crate::httpd::{http_get, http_post};

    #[test]
    fn get_requires_metadata_header_and_api_version() {
        let imds = ImdsHttp::spawn(30).unwrap();
        // Our client always sends Metadata: true, so a wrong api-version is
        // the reachable failure mode.
        let (status, _) = http_get(&format!(
            "{}{}?api-version=1999-01-01",
            imds.base_url(),
            EVENTS_PATH
        ))
        .unwrap();
        assert_eq!(status, 400);
        let (status, body) = http_get(&imds.events_url()).unwrap();
        assert_eq!(status, 200);
        let (inc, events) =
            parse_document(&crate::json::parse(&body).unwrap()).unwrap();
        assert_eq!(inc, 0);
        assert!(events.is_empty());
    }

    #[test]
    fn simulate_eviction_round_trip() {
        let imds = ImdsHttp::spawn(30).unwrap();
        let (status, body) = http_post(
            &format!("{}{}?resource=vm-0", imds.base_url(), SIMULATE_PATH),
            "",
        )
        .unwrap();
        assert_eq!(status, 200, "{body}");
        assert!(body.contains("eventId"));

        let (_, doc) = http_get(&imds.events_url()).unwrap();
        let (inc, events) =
            parse_document(&crate::json::parse(&doc).unwrap()).unwrap();
        assert_eq!(inc, 1);
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].event_type, "Preempt");
        assert_eq!(events[0].resource, "vm-0");
        // notice is ~30 s out from the server's epoch-relative now
        let st = imds.state().lock().unwrap();
        let remaining = events[0].not_before.since(st.now());
        assert!(remaining.as_secs() >= 29, "notice too short: {remaining}");
    }

    #[test]
    fn ack_over_http() {
        let imds = ImdsHttp::spawn(30).unwrap();
        http_post(
            &format!("{}{}?resource=vm-1", imds.base_url(), SIMULATE_PATH),
            "",
        )
        .unwrap();
        let (_, doc) = http_get(&imds.events_url()).unwrap();
        let (_, events) =
            parse_document(&crate::json::parse(&doc).unwrap()).unwrap();
        let ack = format!(
            "{{\"StartRequests\":[{{\"EventId\":\"{}\"}}]}}",
            events[0].event_id
        );
        let (status, body) = http_post(
            &format!("{}{}?api-version={}", imds.base_url(), EVENTS_PATH,
                     API_VERSION),
            &ack,
        )
        .unwrap();
        assert_eq!(status, 200);
        assert!(body.contains("\"acknowledged\":1"), "{body}");
    }

    #[test]
    fn simulate_requires_resource() {
        let imds = ImdsHttp::spawn(30).unwrap();
        let (status, _) =
            http_post(&format!("{}{}", imds.base_url(), SIMULATE_PATH), "")
                .unwrap();
        assert_eq!(status, 400);
    }

    #[test]
    fn bad_json_ack_rejected() {
        let imds = ImdsHttp::spawn(30).unwrap();
        let (status, _) = http_post(
            &format!("{}{}?api-version={}", imds.base_url(), EVENTS_PATH,
                     API_VERSION),
            "not json",
        )
        .unwrap();
        assert_eq!(status, 400);
    }
}
