//! Spot-market traces: replayed price histories and eviction timestamps.
//!
//! The paper's economics (§III, Fig 2) assume a flat 80% spot discount,
//! but real spot markets move: Khatua & Mukherjee provision against EC2
//! price *history*, and Alourani & Kshemkalyani show eviction risk is
//! likewise time-varying. This module makes a pool's price a function of
//! time:
//!
//! * [`PriceTrace`] — a validated, time-ordered sequence of
//!   [`PricePoint`]s. Each point's `factor` multiplies the pool's static
//!   price level (catalog × `price_factor`) from `offset` onwards, as a
//!   step function. A point at offset 0 sets the initial factor; before
//!   any point the factor is `1.0`, so the empty trace is the static
//!   world.
//! * [`PoolTrace`] — the on-disk trace format (`traces/*.trace`): price
//!   points plus per-instance eviction offsets, one directive per line
//!   (see `traces/README.md`). Eviction offsets feed
//!   [`EvictionPlanCfg::Trace`](crate::config::EvictionPlanCfg) — the
//!   k-th `evict` line is the k-th launched instance's notice offset,
//!   measured from that instance's start, matching how the paper
//!   schedules its injections.
//! * [`PriceWalkCfg`] — a seeded geometric random walk that *generates* a
//!   [`PriceTrace`] at fleet construction, so Monte Carlo sweeps get a
//!   different market per seed with no files on disk.
//!
//! The engine replays a pool's trace as a chain of
//! `PoolPriceChanged` events ([`crate::sim::engine::SimEvent`]):
//! placement policies see the moving price through
//! [`PoolView::price_per_hour`](crate::cloud::fleet::PoolView) and
//! re-decide at each replacement, and
//! [`BillingMeter::book_instance_piecewise`](crate::cloud::billing::BillingMeter)
//! bills an instance that straddles a price move per segment.

use crate::simclock::{SimDuration, SimTime};
use crate::util::Prng;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// One step of a price trace: from `offset` (experiment time) onwards,
/// the pool's price is its static level multiplied by `factor`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PricePoint {
    pub offset: SimDuration,
    pub factor: f64,
}

/// A validated price history: strictly time-ordered points with positive
/// finite factors.
#[derive(Debug, Clone, PartialEq)]
pub struct PriceTrace {
    points: Vec<PricePoint>,
}

impl PriceTrace {
    /// Build a trace, rejecting non-finite/non-positive factors and
    /// out-of-order or duplicate offsets up front (mirroring
    /// [`PriceBook::new`](crate::cloud::pricing::PriceBook) — downstream
    /// billing and placement arithmetic never meets garbage).
    pub fn new(points: Vec<PricePoint>) -> Result<Self> {
        for (i, p) in points.iter().enumerate() {
            if !(p.factor.is_finite() && p.factor > 0.0) {
                bail!(
                    "price trace point {i}: factor {} must be positive and \
                     finite",
                    p.factor
                );
            }
            if i > 0 && p.offset <= points[i - 1].offset {
                bail!(
                    "price trace point {i}: offset {} must be strictly after \
                     the previous point ({})",
                    p.offset,
                    points[i - 1].offset
                );
            }
        }
        Ok(Self { points })
    }

    /// A trace that pins the factor to `factor` for the whole run.
    pub fn constant(factor: f64) -> Result<Self> {
        Self::new(vec![PricePoint { offset: SimDuration::ZERO, factor }])
    }

    /// Every point, time-ordered.
    pub fn points(&self) -> &[PricePoint] {
        &self.points
    }

    /// The factor in force at `t` (1.0 before the first point).
    pub fn factor_at(&self, t: SimTime) -> f64 {
        self.points
            .iter()
            .take_while(|p| SimTime::ZERO + p.offset <= t)
            .last()
            .map(|p| p.factor)
            .unwrap_or(1.0)
    }

    /// The factor in force at experiment start (an offset-0 point, else
    /// 1.0). The fleet folds this into the pool's initial price epoch
    /// instead of scheduling an event at t=0.
    pub fn initial_factor(&self) -> f64 {
        match self.points.first() {
            Some(p) if p.offset.is_zero() => p.factor,
            _ => 1.0,
        }
    }

    /// The points the engine must replay as scheduled events — everything
    /// after t=0 (the offset-0 point, if any, is the initial factor).
    pub fn scheduled_points(&self) -> &[PricePoint] {
        match self.points.first() {
            Some(p) if p.offset.is_zero() => &self.points[1..],
            _ => &self.points[..],
        }
    }
}

/// A parsed trace file: the price history plus per-instance eviction
/// offsets (`traces/README.md` documents the format).
#[derive(Debug, Clone, PartialEq)]
pub struct PoolTrace {
    pub price: PriceTrace,
    /// Uptime offset at which the k-th launched instance receives its
    /// eviction notice (consumed in launch order; instances beyond the
    /// list are never evicted).
    pub evictions: Vec<SimDuration>,
}

impl PoolTrace {
    /// Parse the line-oriented trace format:
    ///
    /// ```text
    /// # comment
    /// price <offset_mins> <factor>
    /// evict <uptime_mins>
    /// ```
    pub fn parse(src: &str) -> Result<Self> {
        let mut points = Vec::new();
        let mut evictions = Vec::new();
        for (idx, raw) in src.lines().enumerate() {
            let line_no = idx + 1;
            let line = match raw.find('#') {
                Some(i) => raw[..i].trim(),
                None => raw.trim(),
            };
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split_whitespace();
            let Some(directive) = parts.next() else {
                continue; // whitespace-only line
            };
            match directive {
                "price" => {
                    let (off, factor) = (parts.next(), parts.next());
                    let (Some(off), Some(factor), None) =
                        (off, factor, parts.next())
                    else {
                        bail!(
                            "line {line_no}: expected 'price <offset_mins> \
                             <factor>'"
                        );
                    };
                    let off = parse_mins(off, line_no)?;
                    let factor: f64 = factor.parse().with_context(|| {
                        format!("line {line_no}: bad factor '{factor}'")
                    })?;
                    points.push(PricePoint { offset: off, factor });
                }
                "evict" => {
                    let (Some(off), None) = (parts.next(), parts.next())
                    else {
                        bail!("line {line_no}: expected 'evict <uptime_mins>'");
                    };
                    let off = parse_mins(off, line_no)?;
                    if off.is_zero() {
                        bail!(
                            "line {line_no}: eviction offset must be positive"
                        );
                    }
                    evictions.push(off);
                }
                other => bail!(
                    "line {line_no}: unknown directive '{other}' (expected \
                     'price' or 'evict')"
                ),
            }
        }
        Ok(Self { price: PriceTrace::new(points)?, evictions })
    }

    /// Load and parse a trace file.
    pub fn load(path: &Path) -> Result<Self> {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace {}", path.display()))?;
        Self::parse(&src)
            .with_context(|| format!("parsing trace {}", path.display()))
    }
}

/// Splice market-shock windows into a scheduled price stream
/// (`[chaos.market]`): inside each `[start, end)` window the traced
/// factor is multiplied by `mult`; at `end` the underlying trace's
/// factor is restored. Pure function over the pool's *scheduled* points
/// (offsets > 0; the offset-0 factor arrives as `initial_factor`), so
/// the fleet can rewrite its replay stream before the engine schedules
/// anything. Windows must be time-ordered, non-overlapping, and start
/// after t = 0 — [`crate::sim::chaos::FaultPlan`] draws them that way —
/// which keeps the initial price epoch untouched. The result is a valid
/// scheduled stream: strictly increasing positive offsets, each factor
/// the product of two validated-finite positives, with no-op repeats
/// collapsed.
pub fn splice_price_shocks(
    initial_factor: f64,
    points: &[PricePoint],
    windows: &[(SimDuration, SimDuration)],
    mult: f64,
) -> Vec<PricePoint> {
    let base_at = |t: SimDuration| {
        points
            .iter()
            .take_while(|p| p.offset <= t)
            .last()
            .map(|p| p.factor)
            .unwrap_or(initial_factor)
    };
    let shocked = |t: SimDuration| windows.iter().any(|&(s, e)| s <= t && t < e);
    let mut offs: Vec<SimDuration> = points.iter().map(|p| p.offset).collect();
    for &(s, e) in windows {
        offs.push(s);
        offs.push(e);
    }
    offs.sort();
    offs.dedup();
    let mut out = Vec::with_capacity(offs.len());
    let mut last = initial_factor;
    for t in offs {
        debug_assert!(
            !t.is_zero(),
            "shock windows and scheduled points start after t = 0"
        );
        let f = base_at(t) * if shocked(t) { mult } else { 1.0 };
        if f != last {
            out.push(PricePoint { offset: t, factor: f });
            last = f;
        }
    }
    out
}

fn parse_mins(tok: &str, line_no: usize) -> Result<SimDuration> {
    let mins: f64 = tok
        .parse()
        .with_context(|| format!("line {line_no}: bad offset '{tok}'"))?;
    if !(mins.is_finite() && mins >= 0.0) {
        bail!("line {line_no}: offset {mins} must be finite and non-negative");
    }
    Ok(SimDuration::from_secs_f64(mins * 60.0))
}

/// Seeded geometric random walk over the price factor — generates a
/// [`PriceTrace`] per pool at fleet construction, so wide Monte Carlo
/// sweeps replay a different market per seed without trace files.
#[derive(Debug, Clone, PartialEq)]
pub struct PriceWalkCfg {
    /// Factor at experiment start.
    pub start: f64,
    /// Maximum fractional move per step: each step multiplies the factor
    /// by a uniform draw from `[1 - volatility, 1 + volatility]`.
    pub volatility: f64,
    /// Time between change points.
    pub interval: SimDuration,
    /// Number of change points after the start.
    pub steps: u32,
    /// Factor floor (clamp).
    pub floor: f64,
    /// Factor ceiling (clamp).
    pub ceil: f64,
}

impl Default for PriceWalkCfg {
    fn default() -> Self {
        Self {
            start: 1.0,
            volatility: 0.15,
            interval: SimDuration::from_mins(30),
            steps: 16,
            floor: 0.5,
            ceil: 2.0,
        }
    }
}

impl PriceWalkCfg {
    /// Most change points a walk may generate — far above any plausible
    /// market (100k steps at the default 30-minute interval is ~5.7
    /// simulated years) but low enough that a typo'd `steps` fails fast
    /// instead of sizing a multi-gigabyte per-run allocation.
    pub const MAX_STEPS: u32 = 100_000;

    /// Reject parameter combinations that would generate an invalid
    /// trace (non-positive/non-finite factors, inverted clamp band,
    /// zero step interval, absurd step counts).
    pub fn validate(&self) -> Result<()> {
        if self.steps > Self::MAX_STEPS {
            bail!(
                "price walk steps {} exceeds the {} cap",
                self.steps,
                Self::MAX_STEPS
            );
        }
        for (name, v) in
            [("start", self.start), ("floor", self.floor), ("ceil", self.ceil)]
        {
            if !(v.is_finite() && v > 0.0) {
                bail!("price walk {name} {v} must be positive and finite");
            }
        }
        if !(self.volatility.is_finite()
            && (0.0..1.0).contains(&self.volatility))
        {
            bail!(
                "price walk volatility {} must be in [0, 1)",
                self.volatility
            );
        }
        if self.floor > self.ceil {
            bail!(
                "price walk floor {} exceeds ceiling {}",
                self.floor,
                self.ceil
            );
        }
        if !(self.floor..=self.ceil).contains(&self.start) {
            bail!(
                "price walk start {} outside [{}, {}]",
                self.start,
                self.floor,
                self.ceil
            );
        }
        if self.interval.is_zero() {
            bail!("price walk interval must be positive");
        }
        Ok(())
    }

    /// Generate the walk deterministically from `seed`: the start factor
    /// at offset 0, then `steps` multiplicative moves clamped to
    /// `[floor, ceil]`, one per `interval`.
    pub fn generate(&self, seed: u64) -> Result<PriceTrace> {
        self.validate()?;
        let mut rng = Prng::new(seed ^ 0x5EED_FAC7);
        let mut factor = self.start;
        let mut points =
            vec![PricePoint { offset: SimDuration::ZERO, factor }];
        for i in 1..=self.steps as u64 {
            let step = 1.0 + self.volatility * (2.0 * rng.f64() - 1.0);
            factor = (factor * step).clamp(self.floor, self.ceil);
            points.push(PricePoint {
                offset: SimDuration::from_millis(
                    i * self.interval.as_millis(),
                ),
                factor,
            });
        }
        PriceTrace::new(points)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pt(mins: u64, factor: f64) -> PricePoint {
        PricePoint { offset: SimDuration::from_mins(mins), factor }
    }

    #[test]
    fn factor_is_a_step_function() {
        let t = PriceTrace::new(vec![pt(10, 0.8), pt(60, 1.5)]).unwrap();
        assert_eq!(t.factor_at(SimTime::ZERO), 1.0);
        assert_eq!(t.factor_at(SimTime::from_secs(599)), 1.0);
        assert_eq!(t.factor_at(SimTime::from_secs(600)), 0.8);
        assert_eq!(t.factor_at(SimTime::from_secs(3599)), 0.8);
        assert_eq!(t.factor_at(SimTime::from_secs(3600)), 1.5);
        assert_eq!(t.factor_at(SimTime::from_secs(999_999)), 1.5);
        // no offset-0 point: initial factor is 1.0, both points replay
        assert_eq!(t.initial_factor(), 1.0);
        assert_eq!(t.scheduled_points().len(), 2);
    }

    #[test]
    fn offset_zero_point_folds_into_initial_factor() {
        let t = PriceTrace::new(vec![pt(0, 0.7), pt(30, 1.2)]).unwrap();
        assert_eq!(t.initial_factor(), 0.7);
        assert_eq!(t.scheduled_points(), &[pt(30, 1.2)]);
        let c = PriceTrace::constant(0.9).unwrap();
        assert_eq!(c.initial_factor(), 0.9);
        assert!(c.scheduled_points().is_empty());
        // the empty trace is the static world
        let none = PriceTrace::new(vec![]).unwrap();
        assert_eq!(none.initial_factor(), 1.0);
        assert_eq!(none.factor_at(SimTime::from_secs(1)), 1.0);
    }

    #[test]
    fn rejects_invalid_traces() {
        for bad in [0.0, -0.5, f64::NAN, f64::INFINITY] {
            assert!(PriceTrace::new(vec![pt(0, bad)]).is_err(), "{bad}");
            assert!(PriceTrace::constant(bad).is_err(), "{bad}");
        }
        // out-of-order and duplicate offsets
        assert!(PriceTrace::new(vec![pt(60, 1.0), pt(30, 1.1)]).is_err());
        assert!(PriceTrace::new(vec![pt(30, 1.0), pt(30, 1.1)]).is_err());
    }

    #[test]
    fn parses_trace_files() {
        let t = PoolTrace::parse(
            "# spot market sample\n\
             price 0 0.8   # cheap early\n\
             evict 40\n\
             price 80 1.6\n\
             evict 35.5\n",
        )
        .unwrap();
        assert_eq!(
            t.price.points(),
            &[pt(0, 0.8), pt(80, 1.6)]
        );
        assert_eq!(
            t.evictions,
            vec![SimDuration::from_mins(40), SimDuration::from_millis(2_130_000)]
        );
        // empty file: static prices, no evictions
        let empty = PoolTrace::parse("# nothing\n").unwrap();
        assert!(empty.price.points().is_empty());
        assert!(empty.evictions.is_empty());
    }

    #[test]
    fn rejects_malformed_trace_files() {
        for bad in [
            "price 10",                // missing factor
            "price 10 0.8 extra",     // trailing token
            "price ten 0.8",          // bad offset
            "price 10 fast",          // bad factor
            "price -5 0.8",           // negative offset
            "price 10 -0.8",          // negative factor
            "price 20 1.0\nprice 10 1.1", // out of order
            "evict 0",                // zero eviction offset
            "evict",                  // missing offset
            "surge 10 2.0",           // unknown directive
        ] {
            assert!(PoolTrace::parse(bad).is_err(), "should reject: {bad}");
        }
    }

    fn win(s: u64, e: u64) -> (SimDuration, SimDuration) {
        (SimDuration::from_mins(s), SimDuration::from_mins(e))
    }

    #[test]
    fn splice_multiplies_inside_windows_and_restores_after() {
        // base: 0.8 from start, 1.6 at 80, 1.9 at 160
        let base = vec![pt(80, 1.6), pt(160, 1.9)];
        let out = splice_price_shocks(0.8, &base, &[win(30, 100)], 2.0);
        assert_eq!(
            out,
            vec![pt(30, 1.6), pt(80, 3.2), pt(100, 1.6), pt(160, 1.9)]
        );
        // splice output is itself a valid scheduled stream
        assert!(PriceTrace::new(out).is_ok());
    }

    #[test]
    fn splice_handles_boundary_coincidence_and_multiple_windows() {
        let base = vec![pt(80, 1.6)];
        // window end lands exactly on a base point: one event, not two
        let out = splice_price_shocks(0.8, &base, &[win(40, 80)], 2.0);
        assert_eq!(out, vec![pt(40, 1.6), pt(80, 1.6)]);
        // window start on a base point shocks the new factor directly
        let out = splice_price_shocks(0.8, &base, &[win(80, 120)], 2.0);
        assert_eq!(out, vec![pt(80, 3.2), pt(120, 1.6)]);
        // two disjoint windows each shock and restore
        let out =
            splice_price_shocks(1.0, &[], &[win(10, 20), win(50, 60)], 3.0);
        assert_eq!(
            out,
            vec![pt(10, 3.0), pt(20, 1.0), pt(50, 3.0), pt(60, 1.0)]
        );
    }

    #[test]
    fn splice_with_no_windows_is_the_base_stream() {
        let base = vec![pt(80, 1.6), pt(160, 1.9)];
        assert_eq!(splice_price_shocks(0.8, &base, &[], 2.0), base);
    }

    #[test]
    fn walk_is_deterministic_and_clamped() {
        let cfg = PriceWalkCfg::default();
        let a = cfg.generate(7).unwrap();
        let b = cfg.generate(7).unwrap();
        assert_eq!(a, b, "same seed must generate the same trace");
        let c = cfg.generate(8).unwrap();
        assert_ne!(a, c, "different seeds must decorrelate");
        assert_eq!(a.points().len(), cfg.steps as usize + 1);
        assert_eq!(a.initial_factor(), cfg.start);
        for p in a.points() {
            assert!(
                (cfg.floor..=cfg.ceil).contains(&p.factor),
                "factor {} outside clamp band",
                p.factor
            );
        }
        // offsets advance by exactly one interval per step
        for (i, p) in a.points().iter().enumerate() {
            assert_eq!(
                p.offset.as_millis(),
                i as u64 * cfg.interval.as_millis()
            );
        }
    }

    #[test]
    fn walk_validates_parameters() {
        let ok = PriceWalkCfg::default();
        assert!(ok.validate().is_ok());
        assert!(
            PriceWalkCfg { start: 0.0, ..ok.clone() }.validate().is_err()
        );
        assert!(
            PriceWalkCfg { start: f64::NAN, ..ok.clone() }
                .validate()
                .is_err()
        );
        assert!(
            PriceWalkCfg { volatility: 1.0, ..ok.clone() }
                .validate()
                .is_err()
        );
        assert!(
            PriceWalkCfg { volatility: -0.1, ..ok.clone() }
                .validate()
                .is_err()
        );
        assert!(
            PriceWalkCfg { floor: 3.0, ..ok.clone() }.validate().is_err(),
            "floor above ceiling"
        );
        assert!(
            PriceWalkCfg { start: 0.1, ..ok.clone() }.validate().is_err(),
            "start below floor"
        );
        assert!(
            PriceWalkCfg { interval: SimDuration::ZERO, ..ok.clone() }
                .validate()
                .is_err()
        );
        assert!(
            PriceWalkCfg { steps: PriceWalkCfg::MAX_STEPS + 1, ..ok.clone() }
                .validate()
                .is_err(),
            "absurd step counts must fail fast"
        );
        // steps = 0 is a legal constant market
        let flat = PriceWalkCfg { steps: 0, ..ok }.generate(1).unwrap();
        assert_eq!(flat.points().len(), 1);
        assert!(flat.scheduled_points().is_empty());
    }
}
