//! The virtual cloud: spot/on-demand instances, scale sets, pricing,
//! billing, eviction plans, and the scheduled-events metadata service.
//!
//! This is the substrate the paper assumes (Azure spot VMs + Scale Sets +
//! IMDS + `az vmss simulate-eviction`), rebuilt so its behaviourally
//! relevant parameters — when instances die, how long replacements take,
//! how much notice evictions give, what compute-hours cost — are explicit,
//! configurable, and metered (DESIGN.md §2).

pub mod pricing;
pub mod billing;
pub mod instance;
pub mod eviction;
pub mod metadata;
pub mod scale_set;
pub mod imds_http;

pub use eviction::EvictionPlan;
pub use instance::{Instance, InstanceId, InstanceState};
pub use metadata::{EventStatus, MetadataService, ScheduledEvent};
pub use pricing::{PriceBook, VmSize};
pub use scale_set::ScaleSet;
