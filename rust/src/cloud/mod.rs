//! The virtual cloud: spot/on-demand instances, scale sets, multi-pool
//! fleets, pricing, billing, eviction plans, and the scheduled-events
//! metadata service.
//!
//! This is the substrate the paper assumes (Azure spot VMs + Scale Sets +
//! IMDS + `az vmss simulate-eviction`), rebuilt so its behaviourally
//! relevant parameters — when instances die, how long replacements take,
//! how much notice evictions give, what compute-hours cost — are explicit,
//! configurable, and metered (DESIGN.md §2).
//!
//! Above the single scale set sits the [`fleet`] layer: a [`fleet::Fleet`]
//! owns N pools (each a [`ScaleSet`] with its own price level, eviction
//! plan and provisioning delay) and a pluggable
//! [`fleet::PlacementPolicy`] decides which pool every replacement lands
//! in. The engine drives it through the `ReplacementRequested →
//! PlacementDecided → InstanceProvisioned` event chain; billing is
//! attributed per pool ([`billing::BillingMeter::pool_compute_total`]).
//!
//! Pool prices need not be flat: the [`trace`] module replays empirical
//! (or seeded random-walk) spot-price histories per pool as
//! `PoolPriceChanged` events, placement re-decides as the market moves,
//! and [`billing`] books an instance that straddles a price move
//! piecewise, one line item per price segment (trace files live under
//! `traces/`).

pub mod pricing;
pub mod billing;
pub mod instance;
pub mod eviction;
pub mod metadata;
pub mod scale_set;
pub mod fleet;
pub mod trace;
pub mod imds_http;

pub use eviction::EvictionPlan;
pub use fleet::{Fleet, PlacementPolicy, PoolId, PoolStats, PoolView};
pub use instance::{Instance, InstanceId, InstanceState};
pub use metadata::{EventStatus, MetadataService, ScheduledEvent};
pub use pricing::{PriceBook, VmSize};
pub use scale_set::ScaleSet;
pub use trace::{PoolTrace, PricePoint, PriceTrace, PriceWalkCfg};
