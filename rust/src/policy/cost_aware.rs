//! The cost-aware controller: Young/Daly scaled by the live spot price.
//!
//! A periodic checkpoint freezes the workload for the write, and that
//! frozen time is billed at the pool's *current* hourly price — which
//! the traced markets of [`crate::cloud::trace`] move mid-run. This
//! controller prices that in: it composes on an inner [`YoungDaly`]
//! (same estimator, same δ refinement, same clamp) and multiplies the
//! unclamped optimum by `price_factor ^ sensitivity`, so a pool trading
//! below its catalog level (factor < 1) gets a tighter cadence —
//! checkpoints cluster while the overhead is cheap and the discount
//! signals reclaim risk — while a price spike stretches the interval
//! and stops paying premium rates for protection. `sensitivity` dials
//! how hard the price signal bites (1.0 = linear; validated positive
//! and finite at construction).

use super::young_daly::YoungDaly;
use super::{Clamp, IntervalController, PolicyCtx};
use crate::cloud::fleet::PoolId;
use crate::simclock::{SimDuration, SimTime};

/// `√(2 · δ · MTBF) · price_factor^sensitivity`, clamped.
#[derive(Debug)]
pub struct CostAware {
    /// The Young/Daly core this controller scales: one copy of the
    /// estimator / δ-refinement / clamp logic, not two.
    inner: YoungDaly,
    sensitivity: f64,
    /// Price epochs replayed so far (diagnostic: proves the controller
    /// really saw the market move).
    price_epochs_seen: u64,
}

impl CostAware {
    pub fn new(
        sensitivity: f64,
        prior_mtbf: SimDuration,
        clamp: Clamp,
    ) -> Self {
        Self {
            inner: YoungDaly::new(prior_mtbf, clamp),
            sensitivity,
            price_epochs_seen: 0,
        }
    }

    pub fn price_epochs_seen(&self) -> u64 {
        self.price_epochs_seen
    }
}

impl IntervalController for CostAware {
    fn name(&self) -> &'static str {
        "cost-aware"
    }

    fn next_interval(&mut self, ctx: &PolicyCtx) -> SimDuration {
        let raw = self.inner.raw_interval(ctx);
        // price_factor is validated positive/finite at trace
        // construction, but an extreme factor^sensitivity can still
        // overflow to infinity — saturate at the clamp ceiling instead
        // of feeding mul_f64 a non-finite scale.
        let scale = ctx.price_factor.powf(self.sensitivity);
        let scaled = if scale.is_finite() {
            raw.mul_f64(scale)
        } else {
            self.inner.clamp_max()
        };
        self.inner.clamp_apply(scaled)
    }

    fn observe_launch(&mut self, pool: PoolId, at: SimTime) {
        self.inner.observe_launch(pool, at);
    }

    fn observe_eviction(&mut self, pool: PoolId, at: SimTime) {
        self.inner.observe_eviction(pool, at);
    }

    fn observe_ckpt_cost(&mut self, cost: SimDuration) {
        self.inner.observe_ckpt_cost(cost);
    }

    fn observe_price(&mut self, _pool: PoolId, _factor: f64) {
        self.price_epochs_seen += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClampCfg;

    fn wide_clamp() -> Clamp {
        Clamp::new(&ClampCfg {
            min: SimDuration::from_millis(1),
            max: SimDuration::from_hours(1000),
            hysteresis: 0.0,
        })
        .unwrap()
    }

    fn ctx(price_factor: f64) -> PolicyCtx {
        PolicyCtx {
            now: SimTime::from_secs(3600),
            last_ckpt: SimTime::ZERO,
            base_interval: SimDuration::from_mins(30),
            ckpt_cost: SimDuration::from_secs(12),
            pool: PoolId(0),
            price_factor,
        }
    }

    #[test]
    fn cheap_pools_checkpoint_more_often() {
        let mut c =
            CostAware::new(1.0, SimDuration::from_mins(60), wide_clamp());
        let discount = c.next_interval(&ctx(0.8));
        let catalog = c.next_interval(&ctx(1.0));
        let spiked = c.next_interval(&ctx(1.8));
        assert!(discount < catalog, "{discount} !< {catalog}");
        assert!(catalog < spiked, "{catalog} !< {spiked}");
        // linear sensitivity: the 0.8 factor scales the interval by ~0.8
        let want = catalog.mul_f64(0.8).as_millis() as i64;
        assert!((discount.as_millis() as i64 - want).abs() <= 1);
    }

    #[test]
    fn sensitivity_dials_the_price_response() {
        let mut linear =
            CostAware::new(1.0, SimDuration::from_mins(60), wide_clamp());
        let mut sharp =
            CostAware::new(2.0, SimDuration::from_mins(60), wide_clamp());
        // a spike stretches the sharp controller further
        assert!(sharp.next_interval(&ctx(1.8)) > linear.next_interval(&ctx(1.8)));
        // and a discount tightens it further
        assert!(sharp.next_interval(&ctx(0.8)) < linear.next_interval(&ctx(0.8)));
    }

    #[test]
    fn shares_young_dalys_observations() {
        // The composed inner core sees evictions and commit costs, so
        // at factor 1.0 cost-aware tracks young-daly exactly.
        let mut ca =
            CostAware::new(1.0, SimDuration::from_mins(60), wide_clamp());
        let mut yd = YoungDaly::new(SimDuration::from_mins(60), wide_clamp());
        let mut t = SimTime::ZERO;
        for _ in 0..3 {
            ca.observe_launch(PoolId(0), t);
            yd.observe_launch(PoolId(0), t);
            t = t + SimDuration::from_mins(10);
            ca.observe_eviction(PoolId(0), t);
            yd.observe_eviction(PoolId(0), t);
        }
        ca.observe_ckpt_cost(SimDuration::from_secs(20));
        yd.observe_ckpt_cost(SimDuration::from_secs(20));
        let ctx1 = PolicyCtx { now: t, ..ctx(1.0) };
        assert_eq!(ca.next_interval(&ctx1), yd.next_interval(&ctx1));
    }

    #[test]
    fn counts_observed_price_epochs() {
        let mut c =
            CostAware::new(1.0, SimDuration::from_mins(60), wide_clamp());
        assert_eq!(c.price_epochs_seen(), 0);
        c.observe_price(PoolId(0), 1.6);
        c.observe_price(PoolId(1), 0.9);
        assert_eq!(c.price_epochs_seen(), 2);
    }
}
