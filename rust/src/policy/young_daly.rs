//! The Young/Daly optimal-interval controller.
//!
//! For a workload with checkpoint write cost δ on a machine with mean
//! time between failures M, the first-order optimum periodic checkpoint
//! interval is `√(2 · δ · M)` (Young 1974; Daly 2006 refines the same
//! expansion). The paper fixes its interval offline; this controller
//! derives M online from the run's own eviction stream
//! ([`EvictionRateEstimator`]) and re-evaluates the optimum at every
//! step boundary, so an eviction storm tightens the cadence and a quiet
//! market relaxes it — through the [`Clamp`] so one noisy estimate can't
//! thrash it.
//!
//! [`YoungDaly::with_higher_order`] switches to Daly's higher-order
//! perturbation solution (Daly 2006, eq. 20):
//!
//! ```text
//! t = √(2δM) · [1 + ⅓·√(δ/(2M)) + (1/9)·(δ/(2M))] − δ    for δ < 2M
//! t = M                                                    otherwise
//! ```
//!
//! which matters when δ is no longer negligible against M (an eviction
//! storm shrinking the estimated MTBF toward the write cost) and reduces
//! to the first-order form as δ/M → 0 — a limit the property tests pin.

use super::estimator::EvictionRateEstimator;
use super::{Clamp, IntervalController, PolicyCtx};
use crate::cloud::fleet::PoolId;
use crate::simclock::{SimDuration, SimTime};

/// `√(2 · ckpt_cost · MTBF)` from the online per-pool estimator.
#[derive(Debug)]
pub struct YoungDaly {
    estimator: EvictionRateEstimator,
    clamp: Clamp,
    /// Last observed periodic-commit cost (`observe_ckpt_cost`): once a
    /// real write has landed, its cost replaces the a-priori
    /// `PolicyCtx::ckpt_cost` estimate as δ.
    observed_cost: Option<SimDuration>,
    /// Use Daly's higher-order perturbation solution instead of the
    /// first-order √(2δM) (the `[checkpoint.adaptive] higher_order`
    /// knob; off by default, keeping pinned first-order runs bitwise
    /// intact).
    higher_order: bool,
}

impl YoungDaly {
    pub fn new(prior_mtbf: SimDuration, clamp: Clamp) -> Self {
        Self {
            estimator: EvictionRateEstimator::new(prior_mtbf),
            clamp,
            observed_cost: None,
            higher_order: false,
        }
    }

    /// Toggle Daly's higher-order correction (see the module docs).
    pub fn with_higher_order(mut self, on: bool) -> Self {
        self.higher_order = on;
        self
    }

    /// The Young/Daly first-order optimum, unclamped.
    pub fn optimal_interval(
        ckpt_cost: SimDuration,
        mtbf: SimDuration,
    ) -> SimDuration {
        SimDuration::from_secs_f64(
            (2.0 * ckpt_cost.as_secs_f64() * mtbf.as_secs_f64()).sqrt(),
        )
    }

    /// Daly's higher-order optimum, unclamped: for δ < 2M,
    /// `√(2δM)·[1 + ⅓√(δ/(2M)) + (1/9)(δ/(2M))] − δ`; for δ >= 2M the
    /// expansion breaks down and the optimum saturates at M itself.
    pub fn optimal_interval_higher_order(
        ckpt_cost: SimDuration,
        mtbf: SimDuration,
    ) -> SimDuration {
        let delta = ckpt_cost.as_secs_f64();
        let m = mtbf.as_secs_f64();
        if delta >= 2.0 * m {
            return mtbf;
        }
        let ratio = delta / (2.0 * m); // δ/(2M), in [0, 1)
        let x = ratio.sqrt();
        let t = (2.0 * delta * m).sqrt()
            * (1.0 + x / 3.0 + ratio / 9.0)
            - delta;
        SimDuration::from_secs_f64(t.max(0.0))
    }

    /// The unclamped optimum at this boundary: δ selection (observed
    /// commit cost over the a-priori estimate) + the online MTBF.
    /// [`CostAware`](super::CostAware) composes on this before applying
    /// its price scaling.
    pub fn raw_interval(&self, ctx: &PolicyCtx) -> SimDuration {
        let cost = self.observed_cost.unwrap_or(ctx.ckpt_cost);
        let mtbf = self.estimator.mtbf(ctx.pool, ctx.now);
        if self.higher_order {
            Self::optimal_interval_higher_order(cost, mtbf)
        } else {
            Self::optimal_interval(cost, mtbf)
        }
    }

    pub(crate) fn clamp_apply(&mut self, raw: SimDuration) -> SimDuration {
        self.clamp.apply(raw)
    }

    pub(crate) fn clamp_max(&self) -> SimDuration {
        self.clamp.max()
    }

    pub fn estimator(&self) -> &EvictionRateEstimator {
        &self.estimator
    }
}

impl IntervalController for YoungDaly {
    fn name(&self) -> &'static str {
        "young-daly"
    }

    fn next_interval(&mut self, ctx: &PolicyCtx) -> SimDuration {
        let raw = self.raw_interval(ctx);
        self.clamp.apply(raw)
    }

    fn observe_launch(&mut self, pool: PoolId, at: SimTime) {
        self.estimator.observe_launch(pool, at);
    }

    fn observe_eviction(&mut self, pool: PoolId, at: SimTime) {
        self.estimator.observe_eviction(pool, at);
    }

    fn observe_ckpt_cost(&mut self, cost: SimDuration) {
        self.observed_cost = Some(cost);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClampCfg;
    use crate::util::proptest::{forall, shrink_none, Config};

    fn wide_clamp() -> Clamp {
        Clamp::new(&ClampCfg {
            min: SimDuration::from_millis(1),
            max: SimDuration::from_hours(1000),
            hysteresis: 0.0,
        })
        .unwrap()
    }

    fn ctx(now: SimTime) -> PolicyCtx {
        PolicyCtx {
            now,
            last_ckpt: SimTime::ZERO,
            base_interval: SimDuration::from_mins(30),
            ckpt_cost: SimDuration::from_secs(12),
            pool: PoolId(0),
            price_factor: 1.0,
        }
    }

    #[test]
    fn matches_the_closed_form() {
        // δ = 12 s, M = 60 min → √(2 · 12 · 3600) ≈ 293.9 s
        let got =
            YoungDaly::optimal_interval(
                SimDuration::from_secs(12),
                SimDuration::from_mins(60),
            );
        assert_eq!(got.as_millis(), 293_939);
    }

    #[test]
    fn observed_commit_costs_refine_delta() {
        let mut c = YoungDaly::new(SimDuration::from_mins(60), wide_clamp());
        let a_priori = c.next_interval(&ctx(SimTime::ZERO));
        assert_eq!(a_priori.as_millis(), 293_939);
        // a real commit lands 4x the estimate: δ quadruples, the
        // optimum doubles (√ scaling)
        c.observe_ckpt_cost(SimDuration::from_secs(48));
        let refined = c.next_interval(&ctx(SimTime::ZERO));
        assert_eq!(refined.as_millis(), 587_878);
    }

    #[test]
    fn higher_order_correction_is_off_by_default_and_shortens_intervals() {
        // default-off: the pinned first-order value is untouched
        let mut fo = YoungDaly::new(SimDuration::from_mins(60), wide_clamp());
        assert_eq!(fo.next_interval(&ctx(SimTime::ZERO)).as_millis(), 293_939);
        // on: Daly's correction subtracts δ (net) when δ ≪ M, so the
        // interval comes in below the first-order optimum
        let mut ho = YoungDaly::new(SimDuration::from_mins(60), wide_clamp())
            .with_higher_order(true);
        let corrected = ho.next_interval(&ctx(SimTime::ZERO));
        assert!(
            corrected.as_millis() < 293_939,
            "higher-order {corrected} should undercut first-order 293939ms"
        );
        // δ >= 2M saturates at the MTBF instead of going negative
        let saturated = YoungDaly::optimal_interval_higher_order(
            SimDuration::from_mins(30),
            SimDuration::from_mins(10),
        );
        assert_eq!(saturated, SimDuration::from_mins(10));
    }

    #[test]
    fn prop_higher_order_reduces_to_first_order_in_the_limit() {
        // Satellite pin: as δ/MTBF → 0 the higher-order optimum converges
        // to √(2·δ·MTBF). Analytically the ratio is
        // 1 − ⅔·x + (1/9)·x² with x = √(δ/(2M)), so |ratio − 1| <= x —
        // checked over random (δ, M) pairs spanning five decades of x.
        forall(
            Config::default().cases(300),
            |rng| {
                let delta_ms = rng.range_u64(1, 60_000);
                // MTBF from comparable to δ up to ~10^5 times larger
                let mtbf_ms = delta_ms * rng.range_u64(3, 100_000);
                (delta_ms, mtbf_ms)
            },
            shrink_none,
            |&(delta_ms, mtbf_ms)| {
                let delta = SimDuration::from_millis(delta_ms);
                let mtbf = SimDuration::from_millis(mtbf_ms);
                let fo = YoungDaly::optimal_interval(delta, mtbf)
                    .as_millis() as f64;
                let ho =
                    YoungDaly::optimal_interval_higher_order(delta, mtbf)
                        .as_millis() as f64;
                let x = (delta_ms as f64 / (2.0 * mtbf_ms as f64)).sqrt();
                let ratio = ho / fo;
                // millisecond rounding on both sides: allow 2 ms of slack
                let bound = x + 2.0 / fo;
                if (ratio - 1.0).abs() > bound {
                    return Err(format!(
                        "δ={delta_ms}ms M={mtbf_ms}ms: ratio {ratio} strayed \
                         more than x={x} from 1"
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn an_eviction_storm_tightens_the_cadence() {
        let mut c = YoungDaly::new(SimDuration::from_mins(60), wide_clamp());
        let calm = c.next_interval(&ctx(SimTime::ZERO));
        // four quick evictions: MTBF collapses, interval shrinks
        let mut t = SimTime::ZERO;
        for _ in 0..4 {
            c.observe_launch(PoolId(0), t);
            t = t + SimDuration::from_mins(10);
            c.observe_eviction(PoolId(0), t);
        }
        let stormy = c.next_interval(&ctx(t));
        assert!(
            stormy < calm,
            "storm interval {stormy} should undercut calm {calm}"
        );
    }

    #[test]
    fn prop_interval_shrinks_monotonically_as_rate_rises() {
        // The headline controller-math property: with the checkpoint cost
        // held fixed, a higher estimated eviction rate (smaller MTBF)
        // never yields a longer interval.
        forall(
            Config::default().cases(200),
            |rng| {
                let cost_ms = rng.range_u64(100, 120_000);
                let mut mtbfs: Vec<u64> =
                    (0..8).map(|_| rng.range_u64(1_000, 36_000_000)).collect();
                mtbfs.sort_unstable();
                (cost_ms, mtbfs)
            },
            shrink_none,
            |&(cost_ms, ref mtbfs)| {
                let cost = SimDuration::from_millis(cost_ms);
                let mut prev = SimDuration::ZERO;
                // ascending MTBF == descending rate: intervals ascend
                for &mtbf_ms in mtbfs {
                    let i = YoungDaly::optimal_interval(
                        cost,
                        SimDuration::from_millis(mtbf_ms),
                    );
                    if i < prev {
                        return Err(format!(
                            "interval {i} at mtbf {mtbf_ms}ms below {prev} \
                             at a lower mtbf"
                        ));
                    }
                    prev = i;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_emitted_intervals_respect_the_clamp() {
        // Through the full controller (estimator + clamp): whatever the
        // eviction history, every emitted interval stays within bounds.
        forall(
            Config::default().cases(100),
            |rng| {
                let min = rng.range_u64(1_000, 600_000);
                let max = min + rng.range_u64(0, 3_600_000);
                let evictions: Vec<u64> =
                    (0..rng.range_u64(0, 10))
                        .map(|_| rng.range_u64(1_000, 7_200_000))
                        .collect();
                (min, max, evictions)
            },
            shrink_none,
            |&(min, max, ref evictions)| {
                let clamp = Clamp::new(&ClampCfg {
                    min: SimDuration::from_millis(min),
                    max: SimDuration::from_millis(max),
                    hysteresis: 0.0,
                })
                .map_err(|e| e.to_string())?;
                let mut c =
                    YoungDaly::new(SimDuration::from_mins(60), clamp);
                let mut t = SimTime::ZERO;
                for &uptime in evictions {
                    c.observe_launch(PoolId(0), t);
                    t = t + SimDuration::from_millis(uptime);
                    c.observe_eviction(PoolId(0), t);
                    let i = c.next_interval(&ctx(t));
                    if i.as_millis() < min || i.as_millis() > max {
                        return Err(format!(
                            "interval {i} escaped [{min}ms, {max}ms]"
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
