//! Adaptive checkpoint-interval controllers.
//!
//! The paper picks one periodic checkpoint interval offline and keeps it
//! for the whole run (Table I: transparent/30m), yet its own cost/runtime
//! trade-off hinges on how well that cadence matches the eviction
//! process — and the traced spot markets of [`crate::cloud::trace`] make
//! both eviction risk and price *time-varying* within a run. This module
//! closes that loop online: an [`IntervalController`] is consulted by the
//! engine at every step boundary (the `BoundaryReached` handler in
//! [`crate::sim::engine`]) and answers "how long should the gap to the
//! next periodic checkpoint be, given everything this run has observed?"
//!
//! * [`FixedInterval`] — the identity controller: always the configured
//!   `[checkpoint] interval_mins`, byte-identical to the pre-policy
//!   engine (pinned against the legacy oracle by
//!   `tests/engine_equivalence.rs`).
//! * [`YoungDaly`](young_daly::YoungDaly) — the classic first-order
//!   optimum `√(2 · δ · MTBF)` (Young 1974 / Daly 2006) with δ the
//!   modeled checkpoint write cost and the MTBF estimated online, per
//!   pool, by [`estimator::EvictionRateEstimator`] — fed by the fleet's
//!   launch/eviction observations and surviving across attempts within a
//!   run.
//! * [`CostAware`](cost_aware::CostAware) — Young/Daly scaled by the
//!   active pool's *current* traced price factor raised to a
//!   `sensitivity` exponent: checkpoints cluster while the pool is cheap
//!   (the freeze is billed at the low price) and spread out across a
//!   price spike.
//!
//! Raw controller outputs pass through a composable [`Clamp`] — hard
//! min/max bounds plus a hysteresis dead-band — so a noisy online
//! estimate can never thrash the cadence.
//!
//! ## `[checkpoint.adaptive]` scenario reference
//!
//! ```toml
//! [checkpoint]
//! method = "transparent"      # adaptive controllers require transparent
//! interval_mins = 30          # FixedInterval's cadence
//!
//! [checkpoint.adaptive]
//! controller = "young-daly"   # "fixed" (default) | "young-daly" | "cost-aware"
//! min_interval_mins = 2       # clamp floor    (> 0; default 2)
//! max_interval_mins = 120     # clamp ceiling  (>= floor; default 120)
//! hysteresis = 0.1            # dead-band fraction in [0, 1) (default 0)
//! mtbf_prior_mins = 60        # estimator prior (> 0; default 60)
//! sensitivity = 1.0           # cost-aware only: price-factor exponent (> 0)
//! higher_order = false        # young-daly only: Daly's higher-order form
//! ```
//!
//! Every knob is validated at parse ([`crate::config::ScenarioConfig`])
//! and again at construction ([`build_controller`], mirroring
//! `cloud::fleet::build_policy`): non-finite, zero, or inverted
//! (`min > max`) values are rejected with an error naming the offending
//! key — a NaN sensitivity or a zero floor would otherwise degrade the
//! controller silently.

pub mod cost_aware;
pub mod estimator;
pub mod young_daly;

pub use cost_aware::CostAware;
pub use estimator::EvictionRateEstimator;
pub use young_daly::YoungDaly;

use crate::cloud::fleet::PoolId;
use crate::config::{ClampCfg, IntervalControllerCfg};
use crate::simclock::{SimDuration, SimTime};
use anyhow::{bail, Result};
use std::fmt;

/// Everything a controller may consult when asked for the next interval.
/// Built fresh by the engine at each step boundary.
#[derive(Debug, Clone, Copy)]
pub struct PolicyCtx {
    /// The boundary's instant.
    pub now: SimTime,
    /// When the last periodic checkpoint (or restore/launch reset)
    /// happened — the due test is `now - last_ckpt >= next_interval()`.
    pub last_ckpt: SimTime,
    /// The statically configured transparent interval
    /// (`[checkpoint] interval_mins`): [`FixedInterval`]'s answer.
    pub base_interval: SimDuration,
    /// Modeled cost of one periodic checkpoint write (the snapshot's
    /// transfer time; updated from observed commits as the run goes).
    pub ckpt_cost: SimDuration,
    /// Pool the live instance runs in.
    pub pool: PoolId,
    /// The active pool's current traced price factor (1.0 for static
    /// pools) — what [`CostAware`] scales by.
    pub price_factor: f64,
}

/// Decides the periodic checkpoint cadence online. The engine consults
/// [`IntervalController::next_interval`] at every step boundary and feeds
/// the `observe_*` hooks as the run unfolds; controllers carry their own
/// state (estimators, clamps) across attempts within a run.
pub trait IntervalController: fmt::Debug {
    fn name(&self) -> &'static str;

    /// Interval the periodic-checkpoint due test should use at this
    /// boundary.
    fn next_interval(&mut self, ctx: &PolicyCtx) -> SimDuration;

    /// An instance launched (or resumed) in `pool` at `at`.
    fn observe_launch(&mut self, _pool: PoolId, _at: SimTime) {}

    /// The instance running in `pool` was reclaimed at `at`.
    fn observe_eviction(&mut self, _pool: PoolId, _at: SimTime) {}

    /// A restore from shared storage finished at `at`.
    fn observe_restore(&mut self, _at: SimTime) {}

    /// A periodic checkpoint committed with this write cost.
    fn observe_ckpt_cost(&mut self, _cost: SimDuration) {}

    /// A traced pool's price epoch changed (`PoolPriceChanged`).
    fn observe_price(&mut self, _pool: PoolId, _factor: f64) {}
}

/// The identity controller: the statically configured interval, forever.
/// `FixedInterval` runs are byte-identical to the pre-policy engine — the
/// equivalence suite pins them against the frozen legacy loop.
#[derive(Debug, Default)]
pub struct FixedInterval;

impl IntervalController for FixedInterval {
    fn name(&self) -> &'static str {
        "fixed"
    }

    fn next_interval(&mut self, ctx: &PolicyCtx) -> SimDuration {
        ctx.base_interval
    }
}

/// Validated min/max bounds + hysteresis dead-band over a controller's
/// raw output. The dead-band compares against the last *emitted*
/// interval, so the clamp's output is always within `[min, max]` even
/// while hysteresis is holding an older value.
#[derive(Debug, Clone)]
pub struct Clamp {
    min: SimDuration,
    max: SimDuration,
    hysteresis: f64,
    last: Option<SimDuration>,
}

impl Clamp {
    /// Build from config, rejecting zero bounds, an inverted range, or a
    /// hysteresis outside `[0, 1)` (construction-level mirror of the TOML
    /// validation — builder-API callers get the same errors).
    pub fn new(cfg: &ClampCfg) -> Result<Self> {
        if cfg.min.is_zero() {
            bail!("clamp min interval must be non-zero");
        }
        if cfg.min > cfg.max {
            bail!(
                "clamp min interval ({}) exceeds max ({}) — inverted range",
                cfg.min,
                cfg.max
            );
        }
        if !(cfg.hysteresis.is_finite() && (0.0..1.0).contains(&cfg.hysteresis))
        {
            bail!(
                "clamp hysteresis must be in [0, 1), got {}",
                cfg.hysteresis
            );
        }
        Ok(Self {
            min: cfg.min,
            max: cfg.max,
            hysteresis: cfg.hysteresis,
            last: None,
        })
    }

    /// Clamp `raw` into `[min, max]`, holding the previously emitted
    /// interval when the new one lands inside the hysteresis dead-band.
    pub fn apply(&mut self, raw: SimDuration) -> SimDuration {
        let clamped = raw.clamp(self.min, self.max);
        if let Some(prev) = self.last {
            let delta =
                (clamped.as_millis() as f64 - prev.as_millis() as f64).abs();
            if delta <= self.hysteresis * prev.as_millis() as f64 {
                return prev;
            }
        }
        self.last = Some(clamped);
        clamped
    }

    pub fn min(&self) -> SimDuration {
        self.min
    }

    pub fn max(&self) -> SimDuration {
        self.max
    }
}

/// Build the controller a config names, validating its knobs (the
/// interval-controller mirror of [`crate::cloud::fleet::build_policy`]).
pub fn build_controller(
    cfg: &IntervalControllerCfg,
) -> Result<Box<dyn IntervalController>> {
    Ok(match cfg {
        IntervalControllerCfg::Fixed => Box::new(FixedInterval),
        IntervalControllerCfg::YoungDaly {
            prior_mtbf,
            clamp,
            higher_order,
        } => {
            if prior_mtbf.is_zero() {
                bail!("young-daly mtbf prior must be non-zero");
            }
            Box::new(
                YoungDaly::new(*prior_mtbf, Clamp::new(clamp)?)
                    .with_higher_order(*higher_order),
            )
        }
        IntervalControllerCfg::CostAware {
            sensitivity,
            prior_mtbf,
            clamp,
        } => {
            if !(sensitivity.is_finite() && *sensitivity > 0.0) {
                bail!(
                    "cost-aware sensitivity {sensitivity} must be positive \
                     and finite"
                );
            }
            if prior_mtbf.is_zero() {
                bail!("cost-aware mtbf prior must be non-zero");
            }
            Box::new(CostAware::new(
                *sensitivity,
                *prior_mtbf,
                Clamp::new(clamp)?,
            ))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{forall, shrink_none, Config};

    fn ctx(base_mins: u64) -> PolicyCtx {
        PolicyCtx {
            now: SimTime::from_secs(3600),
            last_ckpt: SimTime::ZERO,
            base_interval: SimDuration::from_mins(base_mins),
            ckpt_cost: SimDuration::from_secs(12),
            pool: PoolId(0),
            price_factor: 1.0,
        }
    }

    #[test]
    fn fixed_interval_is_the_identity() {
        let mut c = FixedInterval;
        assert_eq!(c.name(), "fixed");
        for mins in [5u64, 30, 90] {
            assert_eq!(
                c.next_interval(&ctx(mins)),
                SimDuration::from_mins(mins)
            );
        }
    }

    #[test]
    fn clamp_bounds_and_hysteresis() {
        let mut c = Clamp::new(&ClampCfg {
            min: SimDuration::from_mins(5),
            max: SimDuration::from_mins(60),
            hysteresis: 0.2,
        })
        .unwrap();
        // out-of-range raw values hit the bounds
        assert_eq!(c.apply(SimDuration::from_mins(1)), SimDuration::from_mins(5));
        assert_eq!(
            c.apply(SimDuration::from_hours(5)),
            SimDuration::from_mins(60)
        );
        // a move within 20% of the last emitted value is held...
        assert_eq!(
            c.apply(SimDuration::from_mins(55)),
            SimDuration::from_mins(60)
        );
        // ...a larger one goes through
        assert_eq!(
            c.apply(SimDuration::from_mins(20)),
            SimDuration::from_mins(20)
        );
    }

    #[test]
    fn clamp_rejects_invalid_configs() {
        let bad = [
            ClampCfg {
                min: SimDuration::ZERO,
                max: SimDuration::from_mins(10),
                hysteresis: 0.0,
            },
            ClampCfg {
                min: SimDuration::from_mins(30),
                max: SimDuration::from_mins(10),
                hysteresis: 0.0,
            },
            ClampCfg { hysteresis: 1.0, ..ClampCfg::default() },
            ClampCfg { hysteresis: f64::NAN, ..ClampCfg::default() },
            ClampCfg { hysteresis: -0.1, ..ClampCfg::default() },
        ];
        for cfg in &bad {
            assert!(Clamp::new(cfg).is_err(), "{cfg:?} must be rejected");
        }
        assert!(Clamp::new(&ClampCfg::default()).is_ok());
    }

    #[test]
    fn prop_clamp_output_always_within_bounds() {
        // Whatever the raw stream and hysteresis, every emitted interval
        // lies in [min, max].
        forall(
            Config::default().cases(200),
            |rng| {
                let min = rng.range_u64(1, 10_000);
                let max = min + rng.range_u64(0, 100_000);
                let hysteresis = rng.f64() * 0.999;
                let raws: Vec<u64> =
                    (0..20).map(|_| rng.range_u64(0, 1_000_000)).collect();
                (min, max, hysteresis, raws)
            },
            shrink_none,
            |&(min, max, hysteresis, ref raws)| {
                let mut clamp = Clamp::new(&ClampCfg {
                    min: SimDuration::from_millis(min),
                    max: SimDuration::from_millis(max),
                    hysteresis,
                })
                .map_err(|e| e.to_string())?;
                for &raw in raws {
                    let out = clamp.apply(SimDuration::from_millis(raw));
                    if out < clamp.min() || out > clamp.max() {
                        return Err(format!(
                            "raw {raw} escaped [{min}, {max}]: {out:?}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn build_controller_rejects_invalid_knobs() {
        use crate::config::IntervalControllerCfg as C;
        assert!(build_controller(&C::Fixed).is_ok());
        assert!(build_controller(&C::young_daly()).is_ok());
        assert!(build_controller(&C::cost_aware(1.0)).is_ok());
        for bad in [f64::NAN, f64::INFINITY, 0.0, -2.0] {
            assert!(
                build_controller(&C::cost_aware(bad)).is_err(),
                "sensitivity {bad} must be rejected"
            );
        }
        assert!(build_controller(&C::YoungDaly {
            prior_mtbf: SimDuration::ZERO,
            clamp: ClampCfg::default(),
            higher_order: false,
        })
        .is_err());
        assert!(build_controller(&C::YoungDaly {
            prior_mtbf: SimDuration::from_mins(60),
            clamp: ClampCfg {
                min: SimDuration::from_mins(30),
                max: SimDuration::from_mins(5),
                hysteresis: 0.0,
            },
            higher_order: false,
        })
        .is_err());
    }
}
