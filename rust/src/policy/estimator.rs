//! Online per-pool eviction-rate (MTBF) estimation.
//!
//! Adaptive interval controllers need the mean time between evictions of
//! the pool the workload currently runs in. The fleet already counts
//! launches and evictions per pool; this estimator turns those
//! observations into a running MTBF estimate that survives across
//! attempts within a run:
//!
//! * every launch opens a live uptime interval in its pool;
//! * every eviction closes it, adding the instance's uptime to the
//!   pool's observed-uptime total and bumping its eviction count;
//! * a Bayesian-style prior (one pseudo-eviction after `prior_mtbf` of
//!   uptime) keeps the earliest estimates sane before any eviction has
//!   been observed, and washes out as real evidence accumulates.
//!
//! The estimate at `now` is
//!
//! ```text
//! MTBF(pool, now) = (prior_mtbf + closed_uptime + live_uptime) / (1 + evictions)
//! ```
//!
//! — the censored (still-alive) uptime counts as survival evidence, so a
//! quiet pool's MTBF drifts *up* between evictions instead of freezing at
//! its last failure. On a seeded Poisson eviction plan the estimate
//! converges to the plan's configured mean (property-tested below).

use crate::cloud::fleet::PoolId;
use crate::simclock::{SimDuration, SimTime};

/// Per-pool observations.
#[derive(Debug, Clone, Copy, Default)]
struct PoolObs {
    /// Uptime closed by evictions, milliseconds.
    closed_ms: u64,
    evictions: u64,
    /// Launch instant of the pool's live instance, if any.
    live_since: Option<SimTime>,
}

/// Running MTBF estimator over the fleet's per-pool launch/eviction
/// stream.
#[derive(Debug, Clone)]
pub struct EvictionRateEstimator {
    prior_mtbf: SimDuration,
    pools: Vec<PoolObs>,
}

impl EvictionRateEstimator {
    pub fn new(prior_mtbf: SimDuration) -> Self {
        Self { prior_mtbf, pools: Vec::new() }
    }

    fn obs_mut(&mut self, pool: PoolId) -> &mut PoolObs {
        if pool.0 >= self.pools.len() {
            self.pools.resize_with(pool.0 + 1, PoolObs::default);
        }
        &mut self.pools[pool.0]
    }

    /// An instance started running in `pool` at `at`.
    pub fn observe_launch(&mut self, pool: PoolId, at: SimTime) {
        self.obs_mut(pool).live_since = Some(at);
    }

    /// The instance running in `pool` was reclaimed at `at`.
    pub fn observe_eviction(&mut self, pool: PoolId, at: SimTime) {
        let obs = self.obs_mut(pool);
        if let Some(since) = obs.live_since.take() {
            obs.closed_ms += at.since(since).as_millis();
        }
        obs.evictions += 1;
    }

    /// Evictions observed in `pool` so far.
    pub fn evictions(&self, pool: PoolId) -> u64 {
        self.pools.get(pool.0).map_or(0, |o| o.evictions)
    }

    /// MTBF estimate for `pool` at `now` (includes the live instance's
    /// censored uptime as survival evidence). With no observations this
    /// is exactly the prior.
    pub fn mtbf(&self, pool: PoolId, now: SimTime) -> SimDuration {
        let (uptime_ms, evictions) = match self.pools.get(pool.0) {
            None => (0, 0),
            Some(o) => {
                let live_ms = o
                    .live_since
                    .map_or(0, |since| now.since(since).as_millis());
                (o.closed_ms + live_ms, o.evictions)
            }
        };
        let total = self.prior_mtbf.as_millis() + uptime_ms;
        SimDuration::from_millis(total / (1 + evictions))
    }

    /// Eviction rate (per hour) — `1 / MTBF`, 0 if the estimate is
    /// unbounded.
    pub fn rate_per_hour(&self, pool: PoolId, now: SimTime) -> f64 {
        let mtbf = self.mtbf(pool, now);
        if mtbf.is_zero() {
            0.0
        } else {
            3_600_000.0 / mtbf.as_millis() as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::eviction::EvictionPlan;
    use crate::config::EvictionPlanCfg;
    use crate::util::proptest::{forall, shrink_none, Config};

    const POOL: PoolId = PoolId(0);

    #[test]
    fn prior_holds_until_evidence_arrives() {
        let est = EvictionRateEstimator::new(SimDuration::from_mins(60));
        assert_eq!(est.mtbf(POOL, SimTime::ZERO), SimDuration::from_mins(60));
        assert_eq!(est.evictions(POOL), 0);
    }

    #[test]
    fn censored_uptime_raises_the_estimate() {
        let mut est = EvictionRateEstimator::new(SimDuration::from_mins(60));
        est.observe_launch(POOL, SimTime::ZERO);
        // 2 h alive without an eviction: MTBF grows past the prior
        let at = SimTime::from_secs(7200);
        assert_eq!(est.mtbf(POOL, at), SimDuration::from_mins(180));
    }

    #[test]
    fn evictions_pull_the_estimate_down() {
        let mut est = EvictionRateEstimator::new(SimDuration::from_mins(60));
        let mut t = SimTime::ZERO;
        // four instances each reclaimed after 10 minutes of uptime
        for _ in 0..4 {
            est.observe_launch(POOL, t);
            t = t + SimDuration::from_mins(10);
            est.observe_eviction(POOL, t);
        }
        // (60 + 40) min over 5 intervals = 20 min — well below the prior
        assert_eq!(est.mtbf(POOL, t), SimDuration::from_mins(20));
        assert_eq!(est.evictions(POOL), 4);
        assert!((est.rate_per_hour(POOL, t) - 3.0).abs() < 1e-9);
    }

    #[test]
    fn pools_are_estimated_independently(){
        let mut est = EvictionRateEstimator::new(SimDuration::from_mins(60));
        est.observe_launch(PoolId(1), SimTime::ZERO);
        est.observe_eviction(PoolId(1), SimTime::from_secs(60));
        assert_eq!(est.mtbf(POOL, SimTime::ZERO), SimDuration::from_mins(60));
        assert!(est.mtbf(PoolId(1), SimTime::from_secs(60)) < est.mtbf(POOL, SimTime::from_secs(60)));
    }

    #[test]
    fn prop_estimator_converges_to_seeded_poisson_rate() {
        // Feed the estimator the exact offsets a seeded Poisson eviction
        // plan produces: after many observations the MTBF estimate must
        // sit within 10% of the plan's configured mean.
        forall(
            Config::default().cases(20).seed(0xE57),
            |rng| (rng.next_u64(), rng.range_u64(20, 180)),
            shrink_none,
            |&(seed, mean_mins)| {
                let mean = SimDuration::from_mins(mean_mins);
                let mut plan =
                    EvictionPlan::new(EvictionPlanCfg::Poisson { mean }, seed);
                let mut est =
                    EvictionRateEstimator::new(SimDuration::from_mins(60));
                let mut t = SimTime::ZERO;
                for _ in 0..3000 {
                    let offset = plan
                        .next_eviction_offset()
                        .ok_or("poisson plan ran dry")?;
                    est.observe_launch(PoolId(0), t);
                    t = t + offset;
                    est.observe_eviction(PoolId(0), t);
                }
                let got = est.mtbf(PoolId(0), t).as_secs_f64();
                let want = mean.as_secs_f64();
                if (got - want).abs() / want > 0.10 {
                    return Err(format!(
                        "estimate {got:.1}s vs configured {want:.1}s"
                    ));
                }
                Ok(())
            },
        );
    }
}
