//! `spoton lint` — in-repo determinism & robustness static analysis.
//!
//! Every result this reproduction ships — merge-by-seed sweeps, sharded
//! resumable runs, chaos digests — rests on one contract:
//!
//! > **Byte-identical output at any thread, process or shard count.**
//!
//! The sweeps and shard merges assert that contract *dynamically*, but a
//! dynamic test only catches a hazard once a seed happens to hit it. This
//! module enforces the contract *statically*: a token-level Rust scanner
//! (in the spirit of the in-repo [`crate::util::hash`] / [`crate::json`]
//! utilities — no new dependencies) walks `rust/src`, `rust/benches`,
//! `rust/tests` and `examples/` and flags the constructions that have
//! historically broken reproducibility or crashed long-running restores.
//!
//! ## Rules
//!
//! | id | what it flags | why |
//! |----|---------------|-----|
//! | `D1` | `HashMap`/`HashSet` in digest/report/billing paths | unordered iteration order leaks into output bytes — e.g. summing `f64` pool costs from a `HashMap` makes the billing digest depend on hasher seeds. Use `BTreeMap`/`BTreeSet` or sort first. Applies even in test mods: a test digesting hash order is exactly the flake this stops. |
//! | `D2` | `Instant::now`, `SystemTime`, `thread::current`, `env::var*`, `env::args*`, OS RNG (`OsRng`/`getrandom`/`from_entropy`), `available_parallelism` outside the allowlist | wall-clock and environment reads make two runs of the same seed diverge. Simulated time ([`crate::simclock`]) and seeded [`crate::util::prng`] only; the allowlist covers the genuinely real-world modules (realtime coordinator, bench harness, IMDS HTTP server, shard wall-clock stamps, the CLI entry point). |
//! | `D3` | `.unwrap()` / `.expect(…)` in library code | a panic in the restore path turns a recoverable missing-manifest into a dead coordinator. Propagate `anyhow::Result` with context naming the generation/key involved. Tests, benches and examples are exempt. |
//! | `D4` | truncating `as u32`-and-narrower casts in seed/billing/cell-index math | silent truncation of a seed or cell index corrupts the sweep partition without failing. Use `try_from` so overflow fails loudly. |
//! | `D5` | `Cargo.toml` dependency creep | the crate is anyhow+log only with the optional `pjrt`-gated `xla` binding; anything else must be vendored in-repo. Dev/build dependency sections are creep by definition. |
//! | `A1` | malformed `spoton-lint` allow marker | an allow without a reason (or with an unknown rule id) is a silent hole; it is itself a finding. |
//!
//! ## Escape hatch
//!
//! A justified violation carries an inline marker **with a mandatory
//! reason**:
//!
//! ```text
//! let seq = GUARD.lock().unwrap(); // spoton-lint: allow(D3, reason = "mutex poisoning is unrecoverable")
//! ```
//!
//! A marker trailing code covers its own line only; a marker on a line of
//! its own covers the next line only — an allow can never silently leak
//! onto code it wasn't written for.
//!
//! ## Baseline ratchet
//!
//! Pre-existing debt lives in the committed `analysis/BASELINE.json`
//! ([`baseline::Baseline`]): per `(rule, file)` tolerated counts, written
//! atomically and with sorted keys so it diffs cleanly. `spoton lint`
//! fails on any count *above* baseline (new violation) and on any count
//! *below* it (stale entry — refresh with `--fix-baseline` so the ratchet
//! only moves deliberately). At HEAD the baseline is empty: every finding
//! has either been fixed or carries a reasoned allow marker.
//!
//! ## Running the linter
//!
//! ```text
//! spoton lint                  # scan the repo, exit 1 on non-baseline findings
//! spoton lint --json           # deterministic sorted-key JSON (CI artifacts)
//! spoton lint --fix-baseline   # rewrite analysis/BASELINE.json to current counts
//! spoton lint --root ../repo   # lint a checkout other than cwd
//! ```
//!
//! CI runs `spoton lint` in the `lint-smoke` job next to the clippy gate;
//! the stale-entry check doubles as baseline freshness, so the file can't
//! rot.

pub mod baseline;
pub mod lexer;
pub mod rules;

pub use baseline::{Baseline, Comparison};
pub use rules::{check_cargo_toml, check_source, Diag, RuleId};

use crate::json::Value;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Repo-relative path the baseline lives at.
pub const BASELINE_PATH: &str = "analysis/BASELINE.json";

/// Directory roots scanned for `.rs` files (repo-relative).
pub const SCAN_ROOTS: [&str; 4] =
    ["rust/src", "rust/benches", "rust/tests", "examples"];

/// Manifests checked by the D5 dependency-creep guard (repo-relative).
pub const MANIFESTS: [&str; 2] = ["Cargo.toml", "rust/Cargo.toml"];

/// Path scoping for the rules. All entries are repo-relative prefixes
/// with `/` separators; a file is in scope when its path starts with an
/// entry. The fixture tests re-scope rules onto synthetic files by
/// pushing paths here.
#[derive(Clone, Debug)]
pub struct LintConfig {
    /// D1 scope: paths whose iteration order reaches digests, reports or
    /// billing totals.
    pub ordered_paths: Vec<String>,
    /// D2 allowlist: modules that legitimately touch wall-clock or
    /// environment.
    pub wallclock_allow: Vec<String>,
    /// D4 scope: seed / billing / cell-index arithmetic.
    pub cast_paths: Vec<String>,
    /// Paths exempt from the panic/wall-clock rules (tests, benches,
    /// examples).
    pub exempt_targets: Vec<String>,
    /// Paths not scanned at all (deliberately-violating lint fixtures).
    pub skip: Vec<String>,
    /// D5: the full allowed `[dependencies]` set (plus the optional
    /// `xla` binding, special-cased).
    pub allowed_deps: Vec<String>,
}

impl LintConfig {
    /// The scope this repository is linted with.
    pub fn repo_default() -> LintConfig {
        let v = |xs: &[&str]| -> Vec<String> {
            xs.iter().map(|s| s.to_string()).collect()
        };
        LintConfig {
            ordered_paths: v(&[
                "rust/src/report/",
                "rust/src/json/",
                "rust/src/metrics/",
                "rust/src/cloud/billing.rs",
                "rust/src/cloud/pricing.rs",
                "rust/src/checkpoint/manifest.rs",
                "rust/src/sim/sweep.rs",
                "rust/src/sim/shard.rs",
                "rust/src/sim/cluster.rs",
                "rust/src/sim/chaos.rs",
                "rust/src/util/bench.rs",
            ]),
            wallclock_allow: v(&[
                "rust/src/coordinator/realtime.rs",
                "rust/src/util/bench.rs",
                "rust/src/cloud/imds_http.rs",
                "rust/src/sim/shard.rs",
                "rust/src/runtime/",
                "rust/src/main.rs",
            ]),
            cast_paths: v(&[
                "rust/src/util/prng.rs",
                "rust/src/cloud/billing.rs",
                "rust/src/cloud/pricing.rs",
                "rust/src/sim/shard.rs",
            ]),
            exempt_targets: v(&[
                "rust/tests/",
                "rust/benches/",
                "examples/",
            ]),
            skip: v(&["rust/tests/lint_fixtures/"]),
            allowed_deps: v(&["anyhow", "log"]),
        }
    }
}

/// Deterministic (name-sorted) recursive `.rs` walk under `dir`,
/// accumulating `(repo_relative, absolute)` pairs.
fn walk(
    dir: &Path,
    rel: &str,
    cfg: &LintConfig,
    out: &mut Vec<(String, PathBuf)>,
) -> Result<()> {
    let mut entries: Vec<(String, PathBuf, bool)> = Vec::new();
    let iter = std::fs::read_dir(dir)
        .with_context(|| format!("listing {}", dir.display()))?;
    for entry in iter {
        let entry = entry
            .with_context(|| format!("listing {}", dir.display()))?;
        let name = entry.file_name().to_string_lossy().into_owned();
        let is_dir = entry
            .file_type()
            .with_context(|| format!("stat {}", entry.path().display()))?
            .is_dir();
        entries.push((name, entry.path(), is_dir));
    }
    entries.sort();
    for (name, path, is_dir) in entries {
        let rel_child = format!("{rel}/{name}");
        if cfg.skip.iter().any(|s| {
            rel_child.starts_with(s.as_str())
                || s.trim_end_matches('/') == rel_child
        }) {
            continue;
        }
        if is_dir {
            walk(&path, &rel_child, cfg, out)?;
        } else if name.ends_with(".rs") {
            out.push((rel_child, path));
        }
    }
    Ok(())
}

/// Scan the repository at `root` and return every finding plus the
/// number of files scanned. Findings are sorted by `(path, line, rule)`
/// so output is byte-stable regardless of filesystem order.
pub fn collect_diags(
    root: &Path,
    cfg: &LintConfig,
) -> Result<(Vec<Diag>, usize)> {
    let mut files: Vec<(String, PathBuf)> = Vec::new();
    for scan_root in SCAN_ROOTS {
        let dir = root.join(scan_root);
        if dir.is_dir() {
            walk(&dir, scan_root, cfg, &mut files)?;
        }
    }
    let mut diags: Vec<Diag> = Vec::new();
    let mut scanned = 0usize;
    for (rel, path) in &files {
        let src = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        diags.extend(check_source(rel, &src, cfg));
        scanned += 1;
    }
    for manifest in MANIFESTS {
        let path = root.join(manifest);
        if path.is_file() {
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading {}", path.display()))?;
            diags.extend(check_cargo_toml(manifest, &text, cfg));
            scanned += 1;
        }
    }
    diags.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.rule, a.message.as_str()).cmp(&(
            b.path.as_str(),
            b.line,
            b.rule,
            b.message.as_str(),
        ))
    });
    Ok((diags, scanned))
}

/// Result of one full lint pass: the findings, the baseline verdict and
/// scan stats.
pub struct LintReport {
    pub diags: Vec<Diag>,
    pub comparison: Comparison,
    pub files_scanned: usize,
}

impl LintReport {
    /// True when there is nothing new and nothing stale — the exit-0
    /// condition.
    pub fn clean(&self) -> bool {
        self.comparison.clean()
    }

    /// Human-readable report (deterministic ordering).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for g in &self.comparison.new_groups {
            out.push_str(&format!(
                "NEW {} findings in {} (baselined {}, current {}):\n",
                g.rule, g.path, g.baselined, g.current
            ));
            for d in &g.diags {
                out.push_str(&format!("  {d}\n"));
            }
        }
        for s in &self.comparison.stale {
            out.push_str(&format!(
                "STALE baseline entry {} / {} (baselined {}, current {}) \
                 — run `spoton lint --fix-baseline`\n",
                s.rule, s.path, s.baselined, s.current
            ));
        }
        if self.clean() {
            out.push_str(&format!(
                "spoton lint: clean ({} files scanned, {} baselined \
                 findings)\n",
                self.files_scanned,
                self.diags.len()
            ));
        } else {
            out.push_str(&format!(
                "spoton lint: FAILED ({} new finding group(s), {} stale \
                 baseline entr(y/ies); {} files scanned)\n",
                self.comparison.new_groups.len(),
                self.comparison.stale.len(),
                self.files_scanned,
            ));
        }
        out
    }

    /// Deterministic sorted-key JSON for CI artifacts — same idiom as
    /// `util::bench` reports.
    pub fn to_json(&self) -> Value {
        let diag_json = |d: &Diag| {
            let mut o = Value::obj();
            o.set("file", d.path.as_str());
            o.set("line", u64::from(d.line));
            o.set("message", d.message.as_str());
            o.set("rule", d.rule.as_str());
            o
        };
        let mut new_groups: Vec<Value> = Vec::new();
        for g in &self.comparison.new_groups {
            let findings: Vec<Value> =
                g.diags.iter().map(diag_json).collect();
            let mut o = Value::obj();
            o.set("baselined", g.baselined);
            o.set("current", g.current);
            o.set("file", g.path.as_str());
            o.set("findings", findings);
            o.set("rule", g.rule.as_str());
            new_groups.push(o);
        }
        let mut stale: Vec<Value> = Vec::new();
        for s in &self.comparison.stale {
            let mut o = Value::obj();
            o.set("baselined", s.baselined);
            o.set("current", s.current);
            o.set("file", s.path.as_str());
            o.set("rule", s.rule.as_str());
            stale.push(o);
        }
        let findings: Vec<Value> =
            self.diags.iter().map(diag_json).collect();
        let mut root = Value::obj();
        root.set("clean", self.clean());
        root.set("counts", Baseline::from_diags(&self.diags).to_json());
        root.set("files_scanned", self.files_scanned);
        root.set("findings", findings);
        root.set("new", new_groups);
        root.set("stale", stale);
        root.set("version", 1u64);
        root
    }
}

/// Full lint pass over the repository at `root`: scan, load the
/// baseline, compare.
pub fn lint_repo(root: &Path, cfg: &LintConfig) -> Result<LintReport> {
    let (diags, files_scanned) = collect_diags(root, cfg)?;
    let baseline = Baseline::load(&root.join(BASELINE_PATH))?;
    let comparison = baseline.compare(&diags);
    Ok(LintReport { diags, comparison, files_scanned })
}

/// Rewrite the baseline at `root` to the current findings
/// (`--fix-baseline`). Returns the number of `(rule, file)` groups
/// written.
pub fn fix_baseline(root: &Path, cfg: &LintConfig) -> Result<usize> {
    let (diags, _) = collect_diags(root, cfg)?;
    let base = Baseline::from_diags(&diags);
    let groups: usize =
        base.counts.values().map(|files| files.len()).sum();
    base.save(&root.join(BASELINE_PATH))?;
    Ok(groups)
}
