//! The determinism & robustness rules enforced by `spoton lint`.
//!
//! Each rule carries a machine-readable id (`D1`–`D5`, plus `A1` for
//! malformed allow markers) and produces `file:line` diagnostics. See the
//! [`super`] module docs for the full contract and rationale. Rules are
//! scoped by repo-relative path prefixes carried in
//! [`super::LintConfig`], so the fixture tests can re-scope them onto
//! synthetic files.

use super::lexer::{lex, test_regions, TokKind};
use super::LintConfig;

/// Machine-readable rule identifier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum RuleId {
    /// Unordered-container (`HashMap`/`HashSet`) use in a digest, report
    /// or billing path — iteration order would leak into output bytes.
    D1,
    /// Wall-clock / environment read (`Instant::now`, `SystemTime`,
    /// `thread::current`, `env::var`, OS RNG, `available_parallelism`)
    /// outside the allowlisted real-world modules.
    D2,
    /// `.unwrap()` / `.expect(…)` in library code (tests, benches and
    /// examples exempt).
    D3,
    /// Truncating `as` cast (`as u32` and narrower) in seed, billing or
    /// cell-index arithmetic.
    D4,
    /// Dependency creep in `Cargo.toml` (anyhow + log only; `pjrt`
    /// feature gate must stay).
    D5,
    /// Malformed `spoton-lint` allow marker (missing or empty reason,
    /// unknown rule id).
    A1,
}

impl RuleId {
    pub fn as_str(self) -> &'static str {
        match self {
            RuleId::D1 => "D1",
            RuleId::D2 => "D2",
            RuleId::D3 => "D3",
            RuleId::D4 => "D4",
            RuleId::D5 => "D5",
            RuleId::A1 => "A1",
        }
    }

    pub fn parse(s: &str) -> Option<RuleId> {
        match s {
            "D1" => Some(RuleId::D1),
            "D2" => Some(RuleId::D2),
            "D3" => Some(RuleId::D3),
            "D4" => Some(RuleId::D4),
            "D5" => Some(RuleId::D5),
            "A1" => Some(RuleId::A1),
            _ => None,
        }
    }

    /// All rule ids, for help/summary output.
    pub const ALL: [RuleId; 6] = [
        RuleId::D1,
        RuleId::D2,
        RuleId::D3,
        RuleId::D4,
        RuleId::D5,
        RuleId::A1,
    ];

    /// One-line description, for `render` summaries.
    pub fn summary(self) -> &'static str {
        match self {
            RuleId::D1 => {
                "unordered container in digest/report/billing path"
            }
            RuleId::D2 => "wall-clock/environment read outside allowlist",
            RuleId::D3 => "panicking unwrap/expect in library path",
            RuleId::D4 => "truncating cast in seed/billing/index math",
            RuleId::D5 => "Cargo.toml dependency creep",
            RuleId::A1 => "malformed spoton-lint allow marker",
        }
    }
}

impl std::fmt::Display for RuleId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One finding: rule + repo-relative path + 1-based line + message.
#[derive(Clone, Debug)]
pub struct Diag {
    pub rule: RuleId,
    pub path: String,
    pub line: u32,
    pub message: String,
}

impl std::fmt::Display for Diag {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}:{}: {} {}",
            self.path, self.line, self.rule, self.message
        )
    }
}

/// A parsed `// spoton-lint: allow(D2, reason = "…")` marker. A marker
/// trailing code suppresses the listed rules on its own line; a marker
/// on a line of its own suppresses them on the next line.
struct AllowMarker {
    line: u32,
    rules: Vec<RuleId>,
}

/// Parse every `spoton-lint` marker out of the comment stream; malformed
/// markers become `A1` diagnostics instead of silent no-ops.
fn parse_markers(
    comments: &[(u32, String)],
    path: &str,
    diags: &mut Vec<Diag>,
) -> Vec<AllowMarker> {
    let mut markers = Vec::new();
    for (line, text) in comments {
        let Some(pos) = text.find("spoton-lint:") else {
            continue;
        };
        let rest = text[pos + "spoton-lint:".len()..].trim_start();
        let bad = |why: &str| Diag {
            rule: RuleId::A1,
            path: path.to_string(),
            line: *line,
            message: format!("bad allow marker: {why}"),
        };
        let Some(inner) = rest.strip_prefix("allow(") else {
            diags.push(bad("expected `allow(RULES, reason = \"…\")`"));
            continue;
        };
        let Some(rpos) = inner.find("reason") else {
            diags.push(bad(
                "allow marker requires a `reason = \"…\"` string",
            ));
            continue;
        };
        let after = inner[rpos + "reason".len()..].trim_start();
        let Some(after) = after.strip_prefix('=') else {
            diags.push(bad("expected `reason = \"…\"`"));
            continue;
        };
        let after = after.trim_start();
        let Some(after) = after.strip_prefix('"') else {
            diags.push(bad("reason must be a quoted string"));
            continue;
        };
        let Some(endq) = after.find('"') else {
            diags.push(bad("unterminated reason string"));
            continue;
        };
        if after[..endq].trim().is_empty() {
            diags.push(bad("reason must not be empty"));
            continue;
        }
        let mut rules = Vec::new();
        let mut ok = true;
        for tok in inner[..rpos].split(',') {
            let t = tok.trim();
            if t.is_empty() {
                continue;
            }
            match RuleId::parse(t) {
                Some(r) => rules.push(r),
                None => {
                    diags.push(bad(&format!("unknown rule id '{t}'")));
                    ok = false;
                    break;
                }
            }
        }
        if !ok {
            continue;
        }
        if rules.is_empty() {
            diags.push(bad("no rule ids listed before the reason"));
            continue;
        }
        markers.push(AllowMarker { line: *line, rules });
    }
    markers
}

/// D2 trigger identifiers that are hazardous wherever they appear.
const D2_BARE: [&str; 6] = [
    "Instant",
    "SystemTime",
    "OsRng",
    "getrandom",
    "from_entropy",
    "available_parallelism",
];

/// D2 `a::b` path triggers (`env::var`, `thread::current`, …).
const D2_PATHS: [(&str, &str); 6] = [
    ("env", "var"),
    ("env", "var_os"),
    ("env", "vars"),
    ("env", "args"),
    ("env", "args_os"),
    ("thread", "current"),
];

/// Truncating cast targets for D4.
const D4_NARROW: [&str; 6] = ["u8", "u16", "u32", "i8", "i16", "i32"];

/// Lint one Rust source file. `path` must be repo-relative with `/`
/// separators — rule scoping and the baseline both key on it.
pub fn check_source(path: &str, src: &str, cfg: &LintConfig) -> Vec<Diag> {
    let lexed = lex(src);
    let mut diags: Vec<Diag> = Vec::new();
    let markers = parse_markers(&lexed.comments, path, &mut diags);
    let regions = test_regions(&lexed.toks);
    let in_test =
        |line: u32| regions.iter().any(|&(s, e)| line >= s && line <= e);

    let exempt_target = starts_with_any(path, &cfg.exempt_targets);
    let d1_scope = starts_with_any(path, &cfg.ordered_paths);
    let d2_allowed = starts_with_any(path, &cfg.wallclock_allow);
    let d4_scope = starts_with_any(path, &cfg.cast_paths);

    let toks = &lexed.toks;
    let n = toks.len();
    let mut raw: Vec<Diag> = Vec::new();
    let mut push = |rule: RuleId, line: u32, message: String| {
        raw.push(Diag { rule, path: path.to_string(), line, message });
    };
    for i in 0..n {
        let TokKind::Ident(word) = &toks[i].kind else {
            continue;
        };
        let line = toks[i].line;
        let exempt_here = exempt_target || in_test(line);

        // D1 — unordered containers in ordered (digest/report/billing)
        // paths. Applies even inside test mods: a test that digests a
        // HashMap iteration order is exactly the flake this rule exists
        // to stop.
        if d1_scope && (word == "HashMap" || word == "HashSet") {
            push(
                RuleId::D1,
                line,
                format!(
                    "`{word}` in an ordered (digest/report/billing) path \
                     — use BTreeMap/BTreeSet or sort before iterating"
                ),
            );
        }

        // D2 — wall-clock / environment reads outside the allowlist.
        if !d2_allowed && !exempt_here {
            if D2_BARE.contains(&word.as_str()) {
                push(
                    RuleId::D2,
                    line,
                    format!(
                        "`{word}` outside the wall-clock allowlist — \
                         simulated time / seeded util::prng only"
                    ),
                );
            }
            if i + 3 < n {
                if let (
                    TokKind::Punct(':'),
                    TokKind::Punct(':'),
                    TokKind::Ident(member),
                ) = (&toks[i + 1].kind, &toks[i + 2].kind, &toks[i + 3].kind)
                {
                    if D2_PATHS
                        .iter()
                        .any(|(m, f)| m == word && f == member)
                    {
                        push(
                            RuleId::D2,
                            toks[i + 3].line,
                            format!(
                                "`{word}::{member}` outside the wall-clock \
                                 allowlist — environment reads break \
                                 reproducibility"
                            ),
                        );
                    }
                }
            }
        }

        // D3 — `.unwrap()` / `.expect(` in library code. The `.expect(`
        // form skips a direct `self.expect(` receiver: that is a
        // user-defined method (the JSON parser), not Option::expect.
        if !exempt_here
            && (word == "unwrap" || word == "expect")
            && i >= 1
            && matches!(toks[i - 1].kind, TokKind::Punct('.'))
            && i + 1 < n
            && matches!(toks[i + 1].kind, TokKind::Punct('('))
        {
            let self_recv = i >= 2
                && matches!(&toks[i - 2].kind,
                            TokKind::Ident(w) if w == "self");
            if !(word == "expect" && self_recv) {
                push(
                    RuleId::D3,
                    line,
                    format!(
                        "`.{word}(…)` in a library path — propagate a \
                         Result (or justify with an allow marker)"
                    ),
                );
            }
        }

        // D4 — truncating casts in seed/billing/cell-index math.
        if d4_scope && !exempt_here && word == "as" && i + 1 < n {
            if let TokKind::Ident(target) = &toks[i + 1].kind {
                if D4_NARROW.contains(&target.as_str()) {
                    push(
                        RuleId::D4,
                        line,
                        format!(
                            "truncating `as {target}` cast — use \
                             `{target}::try_from` so overflow fails loudly"
                        ),
                    );
                }
            }
        }
    }

    // Apply allow markers. A marker trailing code covers its own line; a
    // standalone marker covers the next line — so an allow can never
    // silently leak onto code it wasn't written for.
    let code_lines: std::collections::BTreeSet<u32> =
        toks.iter().map(|t| t.line).collect();
    for d in raw {
        let allowed = markers.iter().any(|m| {
            let target = if code_lines.contains(&m.line) {
                m.line
            } else {
                m.line + 1
            };
            target == d.line && m.rules.contains(&d.rule)
        });
        if !allowed {
            diags.push(d);
        }
    }
    diags
}

/// D5 — dependency-creep guard over a `Cargo.toml`. Only the declared
/// dependency set is allowed, dev/build dependency sections are creep by
/// definition, and the `pjrt` feature gate must survive.
pub fn check_cargo_toml(
    path: &str,
    text: &str,
    cfg: &LintConfig,
) -> Vec<Diag> {
    let mut diags = Vec::new();
    let mut section = String::new();
    let mut saw_deps = false;
    let mut features: Vec<String> = Vec::new();
    for (idx, rawline) in text.lines().enumerate() {
        let line_no = idx as u32 + 1;
        let line = match rawline.find('#') {
            Some(h) => rawline[..h].trim(),
            None => rawline.trim(),
        };
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            section = line
                .trim_start_matches('[')
                .trim_end_matches(']')
                .trim()
                .to_string();
            if section == "dependencies" {
                saw_deps = true;
            }
            continue;
        }
        let Some(eq) = line.find('=') else {
            continue;
        };
        let full_key = line[..eq].trim().trim_matches('"');
        let key = match full_key.find('.') {
            Some(d) => &full_key[..d],
            None => full_key,
        };
        match section.as_str() {
            "dependencies" => {
                let allowed = cfg
                    .allowed_deps
                    .iter()
                    .any(|a| a == key)
                    || (key == "xla" && line.contains("optional = true"));
                if !allowed {
                    diags.push(Diag {
                        rule: RuleId::D5,
                        path: path.to_string(),
                        line: line_no,
                        message: format!(
                            "dependency '{key}' is outside the declared \
                             set ({}) — vendor the code in-repo instead",
                            cfg.allowed_deps.join("+"),
                        ),
                    });
                }
            }
            "dev-dependencies" | "build-dependencies" => {
                diags.push(Diag {
                    rule: RuleId::D5,
                    path: path.to_string(),
                    line: line_no,
                    message: format!(
                        "'{key}' in [{section}] — the crate builds with \
                         no dev/build dependencies; use in-repo utilities"
                    ),
                });
            }
            "features" => features.push(key.to_string()),
            _ => {}
        }
    }
    if saw_deps && !features.iter().any(|f| f == "pjrt") {
        diags.push(Diag {
            rule: RuleId::D5,
            path: path.to_string(),
            line: 1,
            message: "the `pjrt` feature gate is missing from [features] \
                      — the stubbed PJRT runtime must stay buildable"
                .to_string(),
        });
    }
    diags
}

fn starts_with_any(path: &str, prefixes: &[String]) -> bool {
    prefixes.iter().any(|p| path.starts_with(p.as_str()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_for(path: &str) -> LintConfig {
        let mut cfg = LintConfig::repo_default();
        // scope every path-keyed rule onto the synthetic file
        cfg.ordered_paths.push(path.to_string());
        cfg.cast_paths.push(path.to_string());
        cfg
    }

    #[test]
    fn d1_fires_on_hashmap_in_ordered_path() {
        let path = "rust/src/report/fake.rs";
        let src = "use std::collections::HashMap;\nfn f(m: &HashMap<u32, f64>) {}\n";
        let diags = check_source(path, src, &cfg_for(path));
        let d1: Vec<_> =
            diags.iter().filter(|d| d.rule == RuleId::D1).collect();
        assert_eq!(d1.len(), 2);
        assert_eq!(d1[0].line, 1);
        assert_eq!(d1[1].line, 2);
    }

    #[test]
    fn d2_fires_outside_allowlist_only() {
        let src = "fn f() { let t = std::time::Instant::now(); }\n";
        let hot = "rust/src/sim/engine_fake.rs";
        let diags = check_source(hot, src, &cfg_for(hot));
        assert!(diags.iter().any(|d| d.rule == RuleId::D2));
        let allowed = "rust/src/util/bench.rs";
        let diags = check_source(allowed, src, &LintConfig::repo_default());
        assert!(diags.is_empty());
    }

    #[test]
    fn d3_skips_tests_and_self_expect() {
        let src = "\
fn lib() { x.unwrap(); self.expect(b'{'); y.expect(\"msg\"); }
#[cfg(test)]
mod tests {
    fn t() { z.unwrap(); }
}
";
        let path = "rust/src/sim/fake.rs";
        let diags = check_source(path, src, &cfg_for(path));
        let d3: Vec<_> =
            diags.iter().filter(|d| d.rule == RuleId::D3).collect();
        assert_eq!(d3.len(), 2, "{d3:?}");
        assert!(d3.iter().all(|d| d.line == 1));
    }

    #[test]
    fn d4_fires_on_narrow_casts_only() {
        let path = "rust/src/util/prng_fake.rs";
        let src = "fn f(x: u64) { let a = x as u32; let b = x as f64; let c = x as usize; }\n";
        let diags = check_source(path, src, &cfg_for(path));
        let d4: Vec<_> =
            diags.iter().filter(|d| d.rule == RuleId::D4).collect();
        assert_eq!(d4.len(), 1);
    }

    #[test]
    fn allow_marker_suppresses_with_reason() {
        let path = "rust/src/sim/fake.rs";
        let src = "\
// spoton-lint: allow(D3, reason = \"invariant: set at construction\")
fn f() { x.unwrap(); }
fn g() { y.unwrap(); } // spoton-lint: allow(D3, reason = \"same line\")
fn h() { z.unwrap(); }
";
        let diags = check_source(path, src, &cfg_for(path));
        let d3: Vec<_> =
            diags.iter().filter(|d| d.rule == RuleId::D3).collect();
        assert_eq!(d3.len(), 1, "{d3:?}");
        assert_eq!(d3[0].line, 4);
    }

    #[test]
    fn allow_marker_without_reason_is_a1_and_does_not_suppress() {
        let path = "rust/src/sim/fake.rs";
        let src = "\
// spoton-lint: allow(D3)
fn f() { x.unwrap(); }
";
        let diags = check_source(path, src, &cfg_for(path));
        assert!(diags.iter().any(|d| d.rule == RuleId::A1));
        assert!(diags.iter().any(|d| d.rule == RuleId::D3));
    }

    #[test]
    fn allow_marker_unknown_rule_is_a1() {
        let path = "rust/src/sim/fake.rs";
        let src = "// spoton-lint: allow(D9, reason = \"nope\")\n";
        let diags = check_source(path, src, &cfg_for(path));
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].rule, RuleId::A1);
        assert!(diags[0].message.contains("D9"));
    }

    #[test]
    fn d5_flags_new_dependency_and_missing_gate() {
        let cfg = LintConfig::repo_default();
        let text = "\
[package]
name = \"x\"

[dependencies]
anyhow = \"1\"
serde = \"1\"

[features]
default = []
";
        let diags = check_cargo_toml("rust/Cargo.toml", text, &cfg);
        assert_eq!(diags.len(), 2, "{diags:?}");
        assert!(diags.iter().all(|d| d.rule == RuleId::D5));
        assert!(diags.iter().any(|d| d.message.contains("serde")));
        assert!(diags.iter().any(|d| d.message.contains("pjrt")));
    }

    #[test]
    fn d5_accepts_the_declared_set() {
        let cfg = LintConfig::repo_default();
        let text = "\
[dependencies]
anyhow = \"1\"
log = \"0.4\"
xla = { path = \"../vendor/xla-rs\", optional = true }

[features]
default = []
pjrt = []
";
        let diags = check_cargo_toml("rust/Cargo.toml", text, &cfg);
        assert!(diags.is_empty(), "{diags:?}");
    }

    #[test]
    fn exempt_targets_skip_panic_rules() {
        let cfg = LintConfig::repo_default();
        let src = "fn f() { x.unwrap(); let t = Instant::now(); }\n";
        let diags = check_source("rust/tests/some_test.rs", src, &cfg);
        assert!(diags.is_empty(), "{diags:?}");
        let diags = check_source("examples/demo.rs", src, &cfg);
        assert!(diags.is_empty(), "{diags:?}");
    }
}
