//! Token-level Rust scanner backing the `spoton lint` rules.
//!
//! This is deliberately *not* a real Rust parser: the determinism rules in
//! [`super::rules`] only need identifier/punctuation sequences with line
//! numbers, with comments and literals out of the way so `"HashMap"` in a
//! string or `.unwrap()` in a doc example never counts. The scanner
//! handles the lexical shapes that actually occur in this repo:
//!
//! * line comments (`//`, `///`, `//!`) and **nested** block comments —
//!   captured as [`Lexed::comments`] so allow markers can be parsed;
//! * string literals with escapes, byte strings (`b"…"`), raw strings
//!   (`r"…"`, `r#"…"#`, `br#"…"#`) and char/byte-char literals, all
//!   reduced to an opaque [`TokKind::Lit`];
//! * lifetimes (`'a`) disambiguated from char literals;
//! * number literals (including `1_000`, `0xff`, `1.5e3` and suffixed
//!   forms) reduced to [`TokKind::Lit`] without swallowing `..` ranges.
//!
//! On top of the token stream, [`test_regions`] finds `#[cfg(test)]`-style
//! modules (any `cfg` attribute whose argument list mentions `test`,
//! including `#[cfg(all(test, feature = "pjrt"))]`) by brace matching, so
//! rules that exempt test code can ask "is this line inside a test mod?".

/// One scanned token.
#[derive(Debug, Clone, PartialEq)]
pub enum TokKind {
    /// Identifier or keyword (`HashMap`, `unwrap`, `mod`, …).
    Ident(String),
    /// Single punctuation character (`.`, `:`, `{`, …).
    Punct(char),
    /// Any literal (string, raw string, char, number) — contents opaque.
    Lit,
}

/// A token with the 1-based source line it starts on.
#[derive(Debug, Clone)]
pub struct Tok {
    pub line: u32,
    pub kind: TokKind,
}

/// Scanner output: code tokens plus the comment text per line (so the
/// `spoton-lint` allow markers can be parsed out of the comments).
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    /// `(line, text)` for each comment, line = where the comment starts.
    pub comments: Vec<(u32, String)>,
}

/// Scan `src` into tokens and comments.
pub fn lex(src: &str) -> Lexed {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line: u32 = 1;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // line comment
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i + 2;
            let mut j = start;
            while j < n && chars[j] != '\n' {
                j += 1;
            }
            out.comments.push((line, chars[start..j].iter().collect()));
            i = j;
            continue;
        }
        // block comment (Rust block comments nest)
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let start_line = line;
            let mut depth = 1usize;
            let mut j = i + 2;
            let mut text = String::new();
            while j < n && depth > 0 {
                if chars[j] == '/' && j + 1 < n && chars[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                    continue;
                }
                if chars[j] == '*' && j + 1 < n && chars[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                    continue;
                }
                if chars[j] == '\n' {
                    line += 1;
                }
                text.push(chars[j]);
                j += 1;
            }
            out.comments.push((start_line, text));
            i = j;
            continue;
        }
        // string literal
        if c == '"' {
            let start_line = line;
            i = skip_string(&chars, i, &mut line);
            out.toks.push(Tok { line: start_line, kind: TokKind::Lit });
            continue;
        }
        // char literal or lifetime
        if c == '\'' {
            if i + 1 < n && chars[i + 1] == '\\' {
                // escaped char literal: '\n', '\'', '\u{…}'
                let mut j = i + 3; // skip quote, backslash, escaped char
                while j < n && chars[j] != '\'' {
                    if chars[j] == '\n' {
                        line += 1;
                    }
                    j += 1;
                }
                out.toks.push(Tok { line, kind: TokKind::Lit });
                i = (j + 1).min(n);
                continue;
            }
            if i + 2 < n && chars[i + 2] == '\'' {
                // plain char literal 'x'
                out.toks.push(Tok { line, kind: TokKind::Lit });
                i += 3;
                continue;
            }
            // lifetime: consume the quote, let the ident lex normally
            i += 1;
            continue;
        }
        // identifier / keyword / raw-string prefix
        if c.is_alphabetic() || c == '_' {
            let start = i;
            let mut j = i;
            while j < n && (chars[j].is_alphanumeric() || chars[j] == '_') {
                j += 1;
            }
            let word: String = chars[start..j].iter().collect();
            if (word == "r" || word == "br")
                && j < n
                && (chars[j] == '"' || chars[j] == '#')
            {
                if let Some(end) = skip_raw_string(&chars, j, &mut line) {
                    out.toks.push(Tok { line, kind: TokKind::Lit });
                    i = end;
                    continue;
                }
                // not a raw string (raw identifier like r#match):
                // fall through and emit the word as an ident
            }
            if word == "b" && j < n && chars[j] == '"' {
                let start_line = line;
                i = skip_string(&chars, j, &mut line);
                out.toks.push(Tok { line: start_line, kind: TokKind::Lit });
                continue;
            }
            if word == "b" && j < n && chars[j] == '\'' {
                // byte-char literal b'x' / b'\n'
                let mut k = j + 1;
                if k < n && chars[k] == '\\' {
                    k += 2;
                }
                while k < n && chars[k] != '\'' {
                    k += 1;
                }
                out.toks.push(Tok { line, kind: TokKind::Lit });
                i = (k + 1).min(n);
                continue;
            }
            out.toks.push(Tok { line, kind: TokKind::Ident(word) });
            i = j;
            continue;
        }
        // number literal (loose: digits, suffixes, hex, underscores)
        if c.is_ascii_digit() {
            let mut j = i;
            while j < n && (chars[j].is_alphanumeric() || chars[j] == '_') {
                j += 1;
            }
            // fractional part — but never swallow `..` range dots
            if j + 1 < n && chars[j] == '.' && chars[j + 1].is_ascii_digit()
            {
                j += 1;
                while j < n && (chars[j].is_alphanumeric() || chars[j] == '_')
                {
                    j += 1;
                }
            }
            out.toks.push(Tok { line, kind: TokKind::Lit });
            i = j;
            continue;
        }
        out.toks.push(Tok { line, kind: TokKind::Punct(c) });
        i += 1;
    }
    out
}

/// Skip a `"…"` literal starting at the opening quote; returns the index
/// just past the closing quote. Handles `\"` / `\\` escapes and counts
/// newlines in multi-line strings — including the newline swallowed by a
/// backslash line-continuation, which still advances the source line.
fn skip_string(chars: &[char], open: usize, line: &mut u32) -> usize {
    let n = chars.len();
    let mut j = open + 1;
    while j < n {
        match chars[j] {
            '\\' => {
                if j + 1 < n && chars[j + 1] == '\n' {
                    *line += 1;
                }
                j += 2;
            }
            '"' => return j + 1,
            c => {
                if c == '\n' {
                    *line += 1;
                }
                j += 1;
            }
        }
    }
    n
}

/// Skip a raw string whose `#`/`"` run starts at `j` (the prefix `r`/`br`
/// was already consumed). Returns `None` when this is not actually a raw
/// string (e.g. a raw identifier `r#match`).
fn skip_raw_string(
    chars: &[char],
    mut j: usize,
    line: &mut u32,
) -> Option<usize> {
    let n = chars.len();
    let mut hashes = 0usize;
    while j < n && chars[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j >= n || chars[j] != '"' {
        return None;
    }
    j += 1;
    while j < n {
        if chars[j] == '\n' {
            *line += 1;
            j += 1;
            continue;
        }
        if chars[j] == '"' {
            let mut k = j + 1;
            let mut h = 0usize;
            while k < n && h < hashes && chars[k] == '#' {
                h += 1;
                k += 1;
            }
            if h == hashes {
                return Some(k);
            }
        }
        j += 1;
    }
    Some(n)
}

/// Inclusive `(start_line, end_line)` ranges of `#[cfg(test)]`-style
/// modules and functions: any `#[cfg(…)]` attribute whose argument list
/// contains the identifier `test`, applied (possibly through further
/// attributes and a `pub` qualifier) to a `mod` or `fn` with a brace
/// body. Bodyless items (`mod tests;`) produce no region.
pub fn test_regions(toks: &[Tok]) -> Vec<(u32, u32)> {
    let mut out = Vec::new();
    let n = toks.len();
    let mut i = 0usize;
    let mut pending = false;
    while i < n {
        // attribute group: # [ … ]
        if matches!(toks[i].kind, TokKind::Punct('#'))
            && i + 1 < n
            && matches!(toks[i + 1].kind, TokKind::Punct('['))
        {
            let mut depth = 0usize;
            let mut j = i + 1;
            let mut has_cfg = false;
            let mut has_test = false;
            while j < n {
                match &toks[j].kind {
                    TokKind::Punct('[') => depth += 1,
                    TokKind::Punct(']') => {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    TokKind::Ident(w) if w == "cfg" => has_cfg = true,
                    TokKind::Ident(w) if w == "test" => has_test = true,
                    _ => {}
                }
                j += 1;
            }
            if has_cfg && has_test {
                pending = true;
            }
            i = j + 1;
            continue;
        }
        if pending {
            match &toks[i].kind {
                TokKind::Ident(w) if w == "pub" => {
                    // `pub` (incl. pub(crate): the parens lex as puncts
                    // and fall through harmlessly below)
                    i += 1;
                    continue;
                }
                TokKind::Ident(w) if w == "mod" || w == "fn" => {
                    let start_line = toks[i].line;
                    let mut j = i;
                    while j < n {
                        match toks[j].kind {
                            TokKind::Punct('{') => break,
                            TokKind::Punct(';') => break,
                            _ => j += 1,
                        }
                    }
                    if j >= n || matches!(toks[j].kind, TokKind::Punct(';'))
                    {
                        pending = false;
                        i = j + 1;
                        continue;
                    }
                    let mut depth = 0usize;
                    while j < n {
                        match toks[j].kind {
                            TokKind::Punct('{') => depth += 1,
                            TokKind::Punct('}') => {
                                depth -= 1;
                                if depth == 0 {
                                    break;
                                }
                            }
                            _ => {}
                        }
                        j += 1;
                    }
                    let end_line = if j < n {
                        toks[j].line
                    } else {
                        toks.last().map_or(start_line, |t| t.line)
                    };
                    out.push((start_line, end_line));
                    pending = false;
                    i = j + 1;
                    continue;
                }
                TokKind::Punct('(' | ')') => {
                    // pub(crate) / pub(super) qualifier parts
                    i += 1;
                    continue;
                }
                TokKind::Ident(w) if w == "crate" || w == "super" => {
                    i += 1;
                    continue;
                }
                _ => {
                    // the cfg(test) attribute guarded something else
                    // (a use item, a const, …) — not a region
                    pending = false;
                }
            }
        }
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .toks
            .into_iter()
            .filter_map(|t| match t.kind {
                TokKind::Ident(w) => Some(w),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn strings_and_comments_are_opaque() {
        let src = r##"
// HashMap in a comment
/* block HashMap /* nested */ still comment */
let a = "HashMap in a string";
let b = r#"raw HashMap"#;
let c = 'x';
let d: &'static str = "s";
real_ident();
"##;
        let ids = idents(src);
        assert!(!ids.iter().any(|w| w == "HashMap"), "{ids:?}");
        assert!(ids.iter().any(|w| w == "real_ident"));
        assert!(ids.iter().any(|w| w == "static"), "lifetime ident kept");
    }

    #[test]
    fn comment_text_and_lines_captured() {
        let src = "let x = 1;\n// spoton-lint: allow(D3, reason = \"ok\")\nlet y = 2;\n";
        let lexed = lex(src);
        assert_eq!(lexed.comments.len(), 1);
        assert_eq!(lexed.comments[0].0, 2);
        assert!(lexed.comments[0].1.contains("spoton-lint"));
    }

    #[test]
    fn line_numbers_survive_multiline_strings() {
        let src = "let s = \"a\nb\nc\";\nafter();\n";
        let lexed = lex(src);
        let after = lexed
            .toks
            .iter()
            .find(|t| matches!(&t.kind, TokKind::Ident(w) if w == "after"))
            .unwrap();
        assert_eq!(after.line, 4);
    }

    #[test]
    fn line_numbers_survive_backslash_continuations() {
        // a `\` line-continuation swallows the newline from the string's
        // *value* but not from the source line count
        let src = "let s = \"first \\\n    second\";\nafter();\n";
        let lexed = lex(src);
        let after = lexed
            .toks
            .iter()
            .find(|t| matches!(&t.kind, TokKind::Ident(w) if w == "after"))
            .unwrap();
        assert_eq!(after.line, 3);
    }

    #[test]
    fn byte_and_escaped_char_literals() {
        let src = "self.expect(b'{')?; let c = '\\''; let d = b\"bytes\";";
        let ids = idents(src);
        assert_eq!(
            ids,
            vec!["self".to_string(), "expect".into(), "let".into(),
                 "c".into(), "let".into(), "d".into()]
        );
    }

    #[test]
    fn test_region_detection() {
        let src = r#"
fn lib_code() { x.unwrap(); }

#[cfg(test)]
mod tests {
    fn helper() { y.unwrap(); }
}

fn more_lib() {}
"#;
        let lexed = lex(src);
        let regions = test_regions(&lexed.toks);
        assert_eq!(regions.len(), 1);
        let (s, e) = regions[0];
        assert!(s >= 4 && s <= 5, "start {s}");
        assert!(e >= 7, "end {e}");
        // lib lines are outside
        assert!(!(s..=e).contains(&2));
        assert!(!(s..=e).contains(&9));
    }

    #[test]
    fn cfg_all_test_feature_counts_as_test_region() {
        let src = "#[cfg(all(test, feature = \"pjrt\"))]\nmod tests {\n    fn f() {}\n}\n";
        let lexed = lex(src);
        assert_eq!(test_regions(&lexed.toks).len(), 1);
    }

    #[test]
    fn cfg_feature_only_is_not_a_test_region() {
        let src = "#[cfg(feature = \"pjrt\")]\nmod real {\n    fn f() {}\n}\n";
        let lexed = lex(src);
        assert!(test_regions(&lexed.toks).is_empty());
    }

    #[test]
    fn bodyless_mod_is_no_region() {
        let src = "#[cfg(test)]\nmod tests;\nfn lib() {}\n";
        let lexed = lex(src);
        assert!(test_regions(&lexed.toks).is_empty());
    }
}
