//! The committed lint baseline: `analysis/BASELINE.json`.
//!
//! The baseline records, per `(rule, file)`, how many findings are
//! *tolerated* while the pre-existing debt burns down. `spoton lint`
//! fails when a file's current count **exceeds** its baselined count
//! (a new violation landed) and also when a baselined count exceeds the
//! current one (the debt shrank but the file wasn't refreshed — a stale
//! baseline would silently absorb the next regression). Counts rather
//! than line numbers keep the file stable under unrelated edits; the
//! ratchet only ever moves via an explicit `spoton lint --fix-baseline`.
//!
//! The file is sorted-key JSON written atomically via
//! [`crate::util::atomic_write`], so it diffs cleanly across PRs and a
//! crashed writer can never leave a torn baseline behind.

use super::rules::Diag;
use crate::json::{self, Value};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Tolerated finding counts: rule id → repo-relative path → count.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct Baseline {
    pub counts: BTreeMap<String, BTreeMap<String, u64>>,
}

/// One `(rule, path)` group whose current findings exceed the baseline.
#[derive(Debug, Clone)]
pub struct NewGroup {
    pub rule: String,
    pub path: String,
    pub baselined: u64,
    pub current: u64,
    /// Every current finding in the group (the new one is among them —
    /// line-level attribution inside a group is not tracked by counts).
    pub diags: Vec<Diag>,
}

/// One baseline entry that no longer matches enough findings.
#[derive(Debug, Clone)]
pub struct StaleEntry {
    pub rule: String,
    pub path: String,
    pub baselined: u64,
    pub current: u64,
}

/// Result of comparing current findings against the baseline.
#[derive(Debug, Default)]
pub struct Comparison {
    pub new_groups: Vec<NewGroup>,
    pub stale: Vec<StaleEntry>,
}

impl Comparison {
    pub fn clean(&self) -> bool {
        self.new_groups.is_empty() && self.stale.is_empty()
    }
}

impl Baseline {
    /// Load from `path`; a missing file is an empty baseline.
    pub fn load(path: &Path) -> Result<Baseline> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(Baseline::default());
            }
            Err(e) => {
                return Err(e).with_context(|| {
                    format!("reading baseline {}", path.display())
                });
            }
        };
        let v = json::parse(&text).with_context(|| {
            format!("parsing baseline {}", path.display())
        })?;
        let version = v.req_u64("version")?;
        if version != 1 {
            bail!("unsupported baseline version {version}");
        }
        let mut counts = BTreeMap::new();
        if let Some(rules) = v.get("rules").and_then(Value::as_object) {
            for (rule, files) in rules {
                let Some(files) = files.as_object() else {
                    bail!("baseline rule '{rule}' is not an object");
                };
                let mut per_file = BTreeMap::new();
                for (file, count) in files {
                    let count = count.as_u64().with_context(|| {
                        format!(
                            "baseline count for {rule} / {file} is not a \
                             non-negative integer"
                        )
                    })?;
                    per_file.insert(file.clone(), count);
                }
                counts.insert(rule.clone(), per_file);
            }
        }
        Ok(Baseline { counts })
    }

    /// Counts of `diags` grouped by `(rule, path)` — what
    /// `--fix-baseline` writes.
    pub fn from_diags(diags: &[Diag]) -> Baseline {
        let mut counts: BTreeMap<String, BTreeMap<String, u64>> =
            BTreeMap::new();
        for d in diags {
            *counts
                .entry(d.rule.as_str().to_string())
                .or_default()
                .entry(d.path.clone())
                .or_default() += 1;
        }
        Baseline { counts }
    }

    /// Serialize as sorted-key JSON.
    pub fn to_json(&self) -> Value {
        let mut rules = Value::obj();
        for (rule, files) in &self.counts {
            let mut per_file = Value::obj();
            for (file, count) in files {
                per_file.set(file, *count);
            }
            rules.set(rule, per_file);
        }
        let mut root = Value::obj();
        root.set("version", 1u64);
        root.set("rules", rules);
        root
    }

    /// Write atomically (rename over the target) with a trailing newline.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut body = json::to_string_pretty(&self.to_json());
        body.push('\n');
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).with_context(|| {
                    format!("creating {}", parent.display())
                })?;
            }
        }
        crate::util::atomic_write(path, body.as_bytes()).with_context(
            || format!("writing baseline {}", path.display()),
        )
    }

    /// Compare current findings against the baseline: groups over budget
    /// are new violations, baseline entries over the current count are
    /// stale.
    pub fn compare(&self, diags: &[Diag]) -> Comparison {
        let current = Baseline::from_diags(diags);
        let mut cmp = Comparison::default();
        for (rule, files) in &current.counts {
            for (file, &count) in files {
                let baselined = self
                    .counts
                    .get(rule)
                    .and_then(|f| f.get(file))
                    .copied()
                    .unwrap_or(0);
                if count > baselined {
                    cmp.new_groups.push(NewGroup {
                        rule: rule.clone(),
                        path: file.clone(),
                        baselined,
                        current: count,
                        diags: diags
                            .iter()
                            .filter(|d| {
                                d.rule.as_str() == rule && &d.path == file
                            })
                            .cloned()
                            .collect(),
                    });
                }
            }
        }
        for (rule, files) in &self.counts {
            for (file, &baselined) in files {
                let count = current
                    .counts
                    .get(rule)
                    .and_then(|f| f.get(file))
                    .copied()
                    .unwrap_or(0);
                if baselined > count {
                    cmp.stale.push(StaleEntry {
                        rule: rule.clone(),
                        path: file.clone(),
                        baselined,
                        current: count,
                    });
                }
            }
        }
        cmp
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::rules::RuleId;

    fn diag(rule: RuleId, path: &str, line: u32) -> Diag {
        Diag {
            rule,
            path: path.to_string(),
            line,
            message: "m".to_string(),
        }
    }

    #[test]
    fn baseline_suppresses_old_but_not_new() {
        let old = vec![
            diag(RuleId::D3, "rust/src/a.rs", 10),
            diag(RuleId::D3, "rust/src/a.rs", 20),
        ];
        let base = Baseline::from_diags(&old);
        // same debt: clean
        assert!(base.compare(&old).clean());
        // one NEW finding in the same file: flagged
        let mut more = old.clone();
        more.push(diag(RuleId::D3, "rust/src/a.rs", 30));
        let cmp = base.compare(&more);
        assert_eq!(cmp.new_groups.len(), 1);
        assert_eq!(cmp.new_groups[0].baselined, 2);
        assert_eq!(cmp.new_groups[0].current, 3);
        assert!(cmp.stale.is_empty());
        // a finding in a different file: flagged independently
        let cmp = base
            .compare(&[old[0].clone(), old[1].clone(),
                       diag(RuleId::D3, "rust/src/b.rs", 1)]);
        assert_eq!(cmp.new_groups.len(), 1);
        assert_eq!(cmp.new_groups[0].path, "rust/src/b.rs");
    }

    #[test]
    fn shrunk_debt_makes_baseline_stale() {
        let old = vec![
            diag(RuleId::D3, "rust/src/a.rs", 10),
            diag(RuleId::D3, "rust/src/a.rs", 20),
        ];
        let base = Baseline::from_diags(&old);
        let cmp = base.compare(&old[..1]);
        assert!(cmp.new_groups.is_empty());
        assert_eq!(cmp.stale.len(), 1);
        assert_eq!(cmp.stale[0].baselined, 2);
        assert_eq!(cmp.stale[0].current, 1);
    }

    #[test]
    fn save_load_round_trip_is_stable() {
        let diags = vec![
            diag(RuleId::D3, "rust/src/a.rs", 1),
            diag(RuleId::D2, "rust/src/b.rs", 2),
            diag(RuleId::D3, "rust/src/b.rs", 3),
        ];
        let base = Baseline::from_diags(&diags);
        let dir = std::env::temp_dir().join(format!(
            "spoton-baseline-{}-{}",
            std::process::id(),
            crate::util::next_seq()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BASELINE.json");
        base.save(&path).unwrap();
        let loaded = Baseline::load(&path).unwrap();
        assert_eq!(loaded, base);
        // byte-stable: saving the loaded baseline reproduces the file
        let first = std::fs::read(&path).unwrap();
        loaded.save(&path).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), first);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_file_is_empty_baseline() {
        let path = std::path::Path::new(
            "/nonexistent/spoton-test/BASELINE.json",
        );
        let base = Baseline::load(path).unwrap();
        assert!(base.counts.is_empty());
    }
}
