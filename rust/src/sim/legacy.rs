//! The original monolithic driver loop, kept verbatim as the frozen
//! reference semantics for the event-driven engine.
//!
//! [`run_reference`] is the pre-refactor `SimDriver::run` body: one
//! ~300-line imperative loop with hand-interleaved time math. It is
//! **not** called by any production path — [`super::engine`] is — but the
//! equivalence suite (`tests/engine_equivalence.rs`) replays every
//! scenario through both and asserts identical [`RunResult`]s, including
//! `final_fingerprint`, costs and timeline ordering. Do not "fix" or
//! extend this file: its value is that it does not change. New behavior
//! goes in the engine, where the equivalence suite will flag any
//! unintended divergence from these semantics.

use super::RunResult;
use crate::checkpoint::{CheckpointStore, CheckpointWriter, CkptKind};
use crate::cloud::billing::BillingMeter;
use crate::cloud::eviction::EvictionPlan;
use crate::cloud::metadata::MetadataService;
use crate::cloud::pricing::PriceBook;
use crate::cloud::scale_set::ScaleSet;
use crate::config::ScenarioConfig;
use crate::coordinator::monitor::ScheduledEventsMonitor;
use crate::coordinator::policy::CheckpointPolicy;
use crate::coordinator::restart::RestartManager;
use crate::metrics::{EventKind, Timeline};
use crate::simclock::{Clock, SimDuration, SimTime};
use crate::storage::SharedStore;
use crate::workload::{StepOutcome, Workload};
use anyhow::{Context, Result};

/// Run one scenario with the legacy imperative loop. Semantics are the
/// contract the event-driven engine must reproduce bit-for-bit.
pub fn run_reference(
    cfg: &ScenarioConfig,
    store: &mut dyn SharedStore,
    factory: &mut dyn FnMut() -> Result<Box<dyn Workload>>,
) -> Result<RunResult> {
    let policy = CheckpointPolicy::new(cfg.checkpoint.clone());
    let mut clock = Clock::new();
    let mut billing = BillingMeter::new();
    let mut timeline = Timeline::with_level(cfg.metrics);
    let mut metadata = MetadataService::new();
    let mut plan = EvictionPlan::new(cfg.eviction.clone(), cfg.seed);
    let mut scale_set = ScaleSet::new(
        &cfg.cloud.vm_size,
        cfg.cloud.spot,
        cfg.cloud.provisioning_delay,
        PriceBook::default(),
    )?;
    let mut writer = CheckpointWriter::new();
    writer.resume_after(CheckpointStore::max_id(store)?);

    let mut workload = factory().context("building workload")?;
    let n_stages = workload.num_stages() as usize;
    if cfg.workload.stage_secs.len() != n_stages {
        anyhow::bail!(
            "scenario has {} stage durations but workload has {} stages",
            cfg.workload.stage_secs.len(),
            n_stages
        );
    }
    let overhead_factor = if cfg.coordinator_attached {
        1.0 + cfg.cloud.coordinator_overhead
    } else {
        1.0
    };
    let spoton = cfg.coordinator_attached;

    // final completion time per stage (re-completions overwrite)
    let mut completion_at: Vec<Option<SimTime>> = vec![None; n_stages];

    let mut notices = 0u32;
    let mut evictions = 0u32;
    let mut periodic_ckpts = 0u32;
    let mut termination_ok = 0u32;
    let mut termination_failed = 0u32;
    let mut app_ckpts = 0u32;
    let mut restores = 0u32;
    let mut lost_steps = 0u64;
    let mut max_steps_seen = 0u64;
    let mut completed = false;
    let mut aborted_reason: Option<String> = None;

    'instances: loop {
        // ---- launch (replacements pay the provisioning delay) ----
        if scale_set.launched() > 0 {
            clock.advance(scale_set.provisioning_delay());
        }
        let inst_id = scale_set.launch(clock.now()).id;
        let inst_start = clock.now();
        timeline.record(
            clock.now(),
            EventKind::InstanceLaunch,
            inst_id.to_string(),
        );
        let mut monitor = ScheduledEventsMonitor::new(&inst_id.to_string());
        monitor.reset();

        // ---- eviction schedule for this instance ----
        // The plan posts the Preempt at `offset` of uptime; the
        // platform will reclaim at notice expiry.
        let notice_post_at =
            plan.next_eviction_offset().map(|o| inst_start + o);
        let deadline = notice_post_at.map(|t| t + cfg.cloud.notice);
        // Coordinator detects at its next poll tick after the post.
        let detect_at = notice_post_at.map(|post| {
            if !spoton {
                // no coordinator: nothing detects; death at deadline
                return post + cfg.cloud.notice;
            }
            let since_start = post.since(inst_start).as_millis();
            let poll = cfg.cloud.poll_interval.as_millis().max(1);
            let ticks = since_start.div_ceil(poll);
            inst_start + SimDuration::from_millis(ticks * poll)
        });

        // ---- restart from the share ----
        if spoton {
            match RestartManager::find_and_restore(
                store,
                &policy,
                workload.as_mut(),
            ) {
                Ok(Some(report)) => {
                    clock.advance(report.cost);
                    restores += 1;
                    lost_steps += max_steps_seen
                        .saturating_sub(report.resumed_total_steps);
                    timeline.record(
                        clock.now(),
                        EventKind::RestoreFromCheckpoint,
                        format!(
                            "ckpt {} ({}) -> step {}",
                            report.manifest.id,
                            report.manifest.kind.as_str(),
                            report.resumed_total_steps
                        ),
                    );
                }
                Ok(None) => {
                    if evictions > 0 {
                        // unprotected restart: begin from scratch
                        workload = factory()?;
                        lost_steps += max_steps_seen;
                    }
                }
                Err(e) => return Err(e).context("restart"),
            }
        } else if evictions > 0 {
            workload = factory()?;
            lost_steps += max_steps_seen;
        }

        let mut last_ckpt_at = clock.now();

        // ---- drive the workload on this instance ----
        loop {
            if clock.now().since(SimTime::ZERO) >= cfg.deadline {
                aborted_reason = Some(format!(
                    "deadline {} exceeded",
                    cfg.deadline
                ));
                scale_set.terminate_current(clock.now(), &mut billing);
                timeline.record(
                    clock.now(),
                    EventKind::Aborted,
                    // spoton-lint: allow(D3, reason = "frozen pre-refactor oracle; aborted runs always carry a reason")
                    aborted_reason.clone().unwrap(),
                );
                break 'instances;
            }

            // periodic transparent checkpoint at step boundary
            if spoton && policy.periodic_due(clock.now(), last_ckpt_at) {
                let snap = workload.snapshot()?;
                let out = writer.write(
                    store,
                    clock.now(),
                    CkptKind::Periodic,
                    workload.as_ref(),
                    &snap,
                )?;
                clock.advance(out.cost()); // freeze while dumping
                if let Some(m) = out.committed() {
                    periodic_ckpts += 1;
                    timeline.record(
                        clock.now(),
                        EventKind::CheckpointCommitted,
                        format!("periodic ckpt {}", m.id),
                    );
                }
                CheckpointStore::gc(store, 3)?;
                last_ckpt_at = clock.now();
            }

            // next step's virtual cost
            let stage = workload.progress().stage as usize;
            let step_cost = SimDuration::from_secs_f64(
                cfg.workload.stage_secs[stage] as f64
                    / workload.stage_steps(stage as u32) as f64
                    * overhead_factor,
            );

            // does the eviction interrupt before this step finishes?
            if let (Some(post), Some(detect), Some(dl)) =
                (notice_post_at, detect_at, deadline)
            {
                let step_end = clock.now() + step_cost;
                if detect <= step_end || dl <= step_end {
                    // the platform posts the notice...
                    let post_visible = post.max(clock.now());
                    timeline.record(
                        post_visible,
                        EventKind::EvictionNotice,
                        metadata.post_preempt(&inst_id.to_string(), dl),
                    );
                    notices += 1;

                    let term_at;
                    if !spoton || detect >= dl {
                        // nobody reacts in time: death at deadline
                        clock.advance_to(dl.max(clock.now()));
                        term_at = clock.now();
                    } else {
                        clock.advance_to(detect.max(clock.now()));
                        // coordinator sees the Preempt
                        let notice = monitor
                            .poll_inproc(&metadata)?
                            .context("notice must be visible")?;
                        if policy.takes_termination_checkpoint() {
                            let budget = dl.since(clock.now());
                            let snap = workload.snapshot()?;
                            let out = writer.write_with_budget(
                                store,
                                clock.now(),
                                CkptKind::Termination,
                                workload.as_ref(),
                                &snap,
                                Some(budget),
                            )?;
                            clock.advance(out.cost());
                            if let Some(m) = out.committed() {
                                termination_ok += 1;
                                timeline.record(
                                    clock.now(),
                                    EventKind::CheckpointCommitted,
                                    format!("termination ckpt {}", m.id),
                                );
                            } else {
                                termination_failed += 1;
                                timeline.record(
                                    clock.now(),
                                    EventKind::CheckpointFailed,
                                    "termination ckpt missed deadline",
                                );
                            }
                        }
                        monitor.ack_inproc(&mut metadata, &notice.event_id);
                        term_at = clock.now();
                    }

                    scale_set.terminate_current(term_at, &mut billing);
                    metadata.clear_resource(&inst_id.to_string());
                    evictions += 1;
                    timeline.record(
                        term_at,
                        EventKind::InstanceEvicted,
                        inst_id.to_string(),
                    );
                    continue 'instances;
                }
            }

            // run the step (real compute)
            clock.advance(step_cost);
            let outcome = workload.step()?;
            max_steps_seen =
                max_steps_seen.max(workload.progress().total_steps);

            let mut milestone = false;
            match outcome {
                StepOutcome::Advanced => {}
                StepOutcome::Milestone => milestone = true,
                StepOutcome::StageComplete(s) => {
                    milestone = true;
                    completion_at[s as usize] = Some(clock.now());
                    timeline.record(
                        clock.now(),
                        EventKind::StageComplete,
                        workload.stage_label(s),
                    );
                }
                StepOutcome::Done => {
                    let s = (workload.num_stages() - 1) as usize;
                    completion_at[s] = Some(clock.now());
                    timeline.record(
                        clock.now(),
                        EventKind::StageComplete,
                        workload.stage_label(s as u32),
                    );
                    timeline.record(
                        clock.now(),
                        EventKind::WorkloadDone,
                        format!(
                            "{} steps",
                            workload.progress().total_steps
                        ),
                    );
                    completed = true;
                    scale_set.terminate_current(clock.now(), &mut billing);
                    break 'instances;
                }
            }

            // application milestone checkpoint (the app writes its own
            // files when app-native checkpointing is enabled)
            if milestone && spoton && policy.persists_app_milestones() {
                if let Some(snap) = workload.app_snapshot()? {
                    let out = writer.write(
                        store,
                        clock.now(),
                        CkptKind::AppNative,
                        workload.as_ref(),
                        &snap,
                    )?;
                    clock.advance(out.cost());
                    if let Some(m) = out.committed() {
                        app_ckpts += 1;
                        timeline.record(
                            clock.now(),
                            EventKind::CheckpointCommitted,
                            format!("application ckpt {}", m.id),
                        );
                    }
                    CheckpointStore::gc(store, 3)?;
                }
            }
        }
    }

    // ---- storage billing over the whole run ----
    let total = clock.now().since(SimTime::ZERO);
    if spoton && policy.protected() {
        billing.book_storage(
            "nfs-share",
            cfg.storage.provisioned_gib,
            total,
            cfg.storage.price_per_100gib_month,
        );
    }

    // ---- stage durations from final completion times ----
    let mut stage_times = Vec::new();
    let mut prev = SimTime::ZERO;
    for (i, at) in completion_at.iter().enumerate() {
        if let Some(t) = at {
            stage_times.push((
                workload.stage_label(i as u32),
                t.since(prev),
            ));
            prev = *t;
        }
    }

    if let Some(reason) = aborted_reason {
        log::warn!("{}: {reason}", cfg.name);
    }

    Ok(RunResult {
        scenario: cfg.name.clone(),
        completed,
        stage_times,
        total,
        notices,
        evictions,
        instances: scale_set.launched(),
        periodic_ckpts,
        termination_ok,
        termination_failed,
        app_ckpts,
        restores,
        lost_steps,
        compute_cost: billing.compute_total(),
        storage_cost: billing.storage_total(),
        invoice: billing.invoice(),
        // The legacy loop predates the fleet; no per-pool attribution,
        // and it predates deadline SLAs too — no verdict, ever.
        // (Mechanical field additions only — semantics untouched.)
        pool_stats: Vec::new(),
        deadline_missed: None,
        timeline,
        final_fingerprint: workload.fingerprint(),
    })
}
