//! The discrete-event simulation engine.
//!
//! One experiment run is a chain of typed [`SimEvent`]s on the
//! deterministic [`EventQueue`]: the engine pops the earliest event,
//! advances the [`Clock`] to it, and dispatches to a small per-concern
//! handler. Handlers never move time themselves — they do their work *at*
//! the current instant and schedule follow-up events at absolute times
//! (`queue.schedule`) or after a modeled cost (`queue.schedule_in`), so
//! every wait the old imperative loop expressed as hand-interleaved
//! `clock.advance` calls is now an explicit event:
//!
//! * an instance dies (or the run begins) →
//!   [`SimEvent::ReplacementRequested`]: the fleet's
//!   [`PlacementPolicy`](crate::cloud::fleet::PlacementPolicy) picks the
//!   pool → [`SimEvent::PlacementDecided`] → the pool provisions →
//!   [`SimEvent::InstanceProvisioned`] (at [`Fleet::ready_at`], not a
//!   blocking advance);
//! * a restore's transfer cost elapses → [`SimEvent::RestoreDone`];
//! * a workload step's virtual compute elapses → [`SimEvent::StepDone`];
//! * a checkpoint write lands → [`SimEvent::CkptDone`] /
//!   [`SimEvent::TerminationCkptDone`];
//! * the platform posts a Preempt → [`SimEvent::NoticePosted`], the
//!   coordinator's poll tick sees it → [`SimEvent::PollTick`] (handled by
//!   [`crate::coordinator::handlers`]), or nobody reacts and the notice
//!   expires → [`SimEvent::NoticeDeadline`];
//! * a traced spot market moves → [`SimEvent::PoolPriceChanged`]
//!   (replayed chain, one pending point per pool —
//!   [`Fleet::price_points`]): the pool opens a new billing epoch, so
//!   placement re-decides at the new price and a straddling instance is
//!   billed per price segment.
//!
//! The periodic-checkpoint cadence is decided at each `BoundaryReached`
//! by an [`IntervalController`] ([`crate::policy`]): the engine feeds it
//! every launch, eviction, restore, checkpoint cost and price epoch, and
//! asks it for the due interval instead of hard-coding
//! `CheckpointPolicy::periodic_due` — a `FixedInterval` controller (the
//! default) reproduces that static test byte for byte.
//!
//! Every schedule is tracked by its cancellation token; when an instance
//! dies or the run finishes, the engine cancels that run's pending timers
//! individually ([`EventQueue::cancel`]) instead of `clear()`-ing the
//! queue — which is what lets multiple runs (the fleet scheduler in
//! [`crate::sched`]) share one queue without trampling each other.
//!
//! ## Semantics
//!
//! On the default single-pool fleet the engine reproduces the legacy loop
//! ([`super::legacy`]) **exactly** — same decisions at the same instants,
//! byte-identical [`RunResult`]s including `final_fingerprint`, billing
//! and timeline order. The equivalence suite
//! (`tests/engine_equivalence.rs`) enforces this over every Table I row
//! and randomized eviction/checkpoint sweeps. Three deliberate
//! consequences:
//!
//! * eviction detection happens at step granularity: the step that would
//!   cross the detection instant never starts (no partial steps), exactly
//!   as the legacy loop decided at each step boundary;
//! * in-flight checkpoint writes are never preempted by a notice — the
//!   notice reaction begins at the next step boundary, as before;
//! * the placement events (`ReplacementRequested`, `PlacementDecided`)
//!   fire at the eviction instant with zero cost and are recorded on the
//!   timeline only for multi-pool fleets, so a 1-pool
//!   [`StickyPool`](crate::cloud::fleet::StickyPool) run's timeline stays
//!   byte-identical to the legacy loop's.

use super::chaos::FaultPlan;
use super::RunResult;
use crate::checkpoint::{CheckpointStore, CheckpointWriter, CkptKind, WriteOutcome};
use crate::cloud::billing::BillingMeter;
use crate::cloud::fleet::{build_policy, Fleet, PlacementPolicy, PoolId};
use crate::cloud::metadata::MetadataService;
use crate::config::ScenarioConfig;
use crate::coordinator::backoff::Backoff;
use crate::coordinator::handlers::{self, PollReaction};
use crate::coordinator::monitor::{Notice, ScheduledEventsMonitor};
use crate::coordinator::policy::CheckpointPolicy;
use crate::coordinator::restart::{RestartManager, RestoreReport};
use crate::metrics::{EventKind, Timeline};
use crate::policy::{build_controller, IntervalController, PolicyCtx};
use crate::simclock::{Clock, EventQueue, SimDuration, SimTime};
use crate::storage::{ChaosStore, FaultKind, InjectedFault, SharedStore};
use crate::workload::{Snapshot, StepOutcome, Workload};
use anyhow::{Context, Result};

/// Everything that can happen in a simulated run.
#[derive(Debug)]
pub enum SimEvent {
    /// The run needs an instance (start of run, or after an eviction):
    /// ask the placement policy for a pool.
    ReplacementRequested,
    /// The placement policy picked `pool`; provisioning starts there.
    PlacementDecided { pool: PoolId },
    /// A (replacement) instance finished provisioning and is Running.
    InstanceProvisioned,
    /// The restore transfer from the share finished.
    RestoreDone { report: RestoreReport },
    /// The workload sits at a step boundary: decide what happens next
    /// (abort, periodic checkpoint, eviction reaction, or the next step).
    BoundaryReached,
    /// One workload step's virtual compute elapsed; execute it.
    StepDone,
    /// A periodic (`periodic == true`) or application-milestone checkpoint
    /// write finished.
    CkptDone { periodic: bool, outcome: WriteOutcome },
    /// The platform posted the Preempt for the current instance.
    NoticePosted,
    /// The coordinator's scheduled-events poll tick that surfaces the
    /// posted notice.
    PollTick,
    /// The notice expired with nobody reacting (no coordinator, or the
    /// poll tick lands after the reclaim instant): the platform kills the
    /// instance.
    NoticeDeadline,
    /// The opportunistic termination checkpoint race finished (committed
    /// or dead mid-transfer).
    TerminationCkptDone { outcome: WriteOutcome, notice: Notice },
    /// The instance is reclaimed.
    InstanceEvicted,
    /// The spot market moved: apply point `idx` of `pool`'s price trace
    /// (and schedule the next point). These events belong to the *run*,
    /// not to any instance — an eviction never cancels them.
    PoolPriceChanged { pool: PoolId, idx: usize },
    /// A planned eviction storm (chaos): rewrite the live instance's
    /// eviction schedule to post a notice immediately. Like price
    /// changes, storms belong to the run, not to any instance.
    ChaosStorm { idx: usize },
    /// A failed checkpoint write's backoff delay elapsed: take attempt
    /// `attempt` (0-based) of the same capture.
    CkptRetry { periodic: bool, attempt: u32 },
}

/// When the platform will post/enforce the eviction of one instance.
#[derive(Debug, Clone, Copy)]
struct EvictionSchedule {
    /// Preempt appears in the scheduled-events document.
    post: SimTime,
    /// First coordinator poll tick at/after `post` (== `deadline` when no
    /// coordinator is attached: nothing ever detects).
    detect: SimTime,
    /// `NotBefore`: the platform reclaims at this instant.
    deadline: SimTime,
}

/// The currently-running instance.
#[derive(Debug)]
struct InstanceCtx {
    id: String,
    schedule: Option<EvictionSchedule>,
    /// Launch instant — poll ticks are measured from here, so a storm
    /// rewriting the schedule can land `detect` on a real tick boundary.
    started: SimTime,
    /// Bid carried from the pool at launch (`[pool.NAME] bid`); `None`
    /// bids the going rate and is never outbid.
    bid: Option<f64>,
    /// Set when a price move crossed the bid: billing stops at this
    /// instant even though the notice window still runs to the reclaim.
    outbid_at: Option<SimTime>,
}

/// The engine: event queue + clock + run accounting around the same
/// policy/monitor/restart/writer pieces the real-time coordinator uses,
/// drawing instances from a multi-pool [`Fleet`].
pub struct Engine<'a> {
    cfg: &'a ScenarioConfig,
    /// The share, behind the chaos wrapper. With `[chaos]` absent this is
    /// a passthrough: pure delegation, no PRNG draws, byte-identical to
    /// the bare store.
    store: ChaosStore<&'a mut dyn SharedStore>,
    factory: &'a mut dyn FnMut() -> Result<Box<dyn Workload>>,

    clock: Clock,
    queue: EventQueue<SimEvent>,
    /// Cancellation tokens of this run's in-flight events. On a shared
    /// queue, instance death cancels exactly these — never other runs'.
    live_tokens: Vec<u64>,
    /// Tokens of pending price-trace replays. Tracked apart from
    /// `live_tokens`: price changes outlive instances (an eviction must
    /// not cancel the market), but the run's end still drains them.
    price_tokens: Vec<u64>,
    /// Tokens of pending chaos storms — run-scoped like the market.
    chaos_tokens: Vec<u64>,
    /// Token of a pending `NoticePosted`, so a storm can pull an already
    /// decided (but not yet posted) eviction forward to "now".
    notice_token: Option<u64>,

    policy: CheckpointPolicy,
    /// Tunes the periodic-checkpoint cadence online
    /// ([`crate::policy`]): consulted at every step boundary, fed by the
    /// launch/eviction/price observations below. The default
    /// `FixedInterval` reproduces `CheckpointPolicy::periodic_due`
    /// byte for byte.
    controller: Box<dyn IntervalController>,
    /// A-priori cost of one periodic checkpoint write (the modeled
    /// snapshot transfer time) — the δ estimate handed to controllers
    /// via `PolicyCtx`; observed commit costs reach them through the
    /// `observe_ckpt_cost` hook instead of mutating this.
    ckpt_cost_est: SimDuration,
    billing: BillingMeter,
    timeline: Timeline,
    metadata: MetadataService,
    fleet: Fleet,
    placement: Box<dyn PlacementPolicy>,
    writer: CheckpointWriter,
    workload: Box<dyn Workload>,
    monitor: Option<ScheduledEventsMonitor>,
    inst: Option<InstanceCtx>,
    /// Reusable periodic-snapshot buffer: one allocation per run, not one
    /// per checkpoint (`Workload::snapshot_into`).
    snap_buf: Snapshot,
    /// The run's fault schedule (storms + IMDS outages); empty with
    /// `[chaos]` absent.
    plan: FaultPlan,
    /// Retry policy for failed checkpoint commits (`[checkpoint.retry]`);
    /// `None` fails the generation on the first storage error.
    backoff: Option<Backoff>,
    /// Are we currently inside an observed IMDS outage? (Drives the
    /// one-record-per-outage transition on the timeline.)
    imds_was_down: bool,

    spoton: bool,
    overhead_factor: f64,
    last_ckpt_at: SimTime,
    completion_at: Vec<Option<SimTime>>,
    notices: u32,
    evictions: u32,
    periodic_ckpts: u32,
    termination_ok: u32,
    termination_failed: u32,
    app_ckpts: u32,
    restores: u32,
    lost_steps: u64,
    max_steps_seen: u64,
    completed: bool,
    aborted_reason: Option<String>,
    finished: bool,
}

impl<'a> Engine<'a> {
    /// Build the engine for one scenario (validates the workload against
    /// the scenario calibration, exactly like the legacy driver did).
    pub fn new(
        cfg: &'a ScenarioConfig,
        store: &'a mut dyn SharedStore,
        factory: &'a mut dyn FnMut() -> Result<Box<dyn Workload>>,
    ) -> Result<Self> {
        let workload = factory().context("building workload")?;
        let n_stages = workload.num_stages() as usize;
        if cfg.workload.stage_secs.len() != n_stages {
            anyhow::bail!(
                "scenario has {} stage durations but workload has {} stages",
                cfg.workload.stage_secs.len(),
                n_stages
            );
        }
        let fleet = Fleet::from_scenario(cfg)?;
        let placement = build_policy(&cfg.fleet.placement)?;
        // The policy carries the controller selection; the live
        // controller is built from that one copy so the two can't drift.
        let policy = CheckpointPolicy::new(cfg.checkpoint.clone())
            .with_compression(cfg.compress_termination)
            .with_controller(cfg.adaptive.clone());
        // Builder-API mirror of the `[checkpoint.adaptive]` parse rule:
        // an adaptive controller without a periodic interval to tune
        // would silently never be consulted.
        if policy.periodic_interval().is_none()
            && *policy.controller() != crate::config::IntervalControllerCfg::Fixed
        {
            anyhow::bail!(
                "adaptive interval controller '{}' requires the transparent \
                 checkpoint method (it tunes the periodic interval)",
                policy.controller().label()
            );
        }
        let controller = build_controller(policy.controller())?;
        // A-priori checkpoint-cost estimate from the modeled image size
        // (the same estimate a CRIU pre-dump makes); controllers refine
        // it from observed commits via `observe_ckpt_cost`.
        let ckpt_cost_est = store.transfer_cost(
            (cfg.workload.state_gib * (1u64 << 30) as f64) as u64,
        );
        let spoton = cfg.coordinator_attached;
        // Chaos wrapping: with `[chaos]` absent the wrapper is a pure
        // passthrough and the plan is empty — nothing is armed, nothing
        // draws, every digest stays byte-identical.
        let (store, plan) = match &cfg.chaos {
            Some(chaos) => (
                ChaosStore::new(
                    store,
                    chaos.storage.clone(),
                    super::chaos::storage_seed(cfg.seed, chaos.salt),
                ),
                FaultPlan::draw(chaos, cfg.seed),
            ),
            None => (ChaosStore::passthrough(store), FaultPlan::none()),
        };
        let backoff = cfg
            .retry
            .as_ref()
            .map(|r| {
                Backoff::new(r.clone(), super::chaos::backoff_seed(cfg.seed))
            })
            .transpose()?;
        Ok(Self {
            policy,
            controller,
            ckpt_cost_est,
            overhead_factor: if spoton {
                1.0 + cfg.cloud.coordinator_overhead
            } else {
                1.0
            },
            spoton,
            clock: Clock::new(),
            queue: EventQueue::new(),
            live_tokens: Vec::new(),
            price_tokens: Vec::new(),
            chaos_tokens: Vec::new(),
            notice_token: None,
            plan,
            backoff,
            imds_was_down: false,
            billing: BillingMeter::new(),
            timeline: Timeline::with_level(cfg.metrics),
            metadata: MetadataService::new(),
            fleet,
            placement,
            writer: CheckpointWriter::new(),
            completion_at: vec![None; n_stages],
            workload,
            monitor: None,
            inst: None,
            snap_buf: Snapshot { bytes: Vec::new(), charged_bytes: 0 },
            last_ckpt_at: SimTime::ZERO,
            notices: 0,
            evictions: 0,
            periodic_ckpts: 0,
            termination_ok: 0,
            termination_failed: 0,
            app_ckpts: 0,
            restores: 0,
            lost_steps: 0,
            max_steps_seen: 0,
            completed: false,
            aborted_reason: None,
            finished: false,
            cfg,
            store,
            factory,
        })
    }

    /// Run to completion (workload Done) or abort (scenario deadline).
    pub fn run(mut self) -> Result<RunResult> {
        self.writer
            .resume_after(CheckpointStore::max_id(&mut self.store)?);
        self.schedule(SimTime::ZERO, SimEvent::ReplacementRequested);
        // market shocks rewrite the traced pools' replay streams before
        // anything is scheduled; with `[chaos.market]` absent the plan
        // carries no windows and the streams are untouched
        self.fleet.splice_market_shocks(
            &self.plan.market_shocks,
            self.plan.market_factor,
        );
        self.schedule_price_traces();
        self.schedule_storms();
        while let Some(sch) = self.queue.pop() {
            self.live_tokens.retain(|&t| t != sch.seq);
            self.price_tokens.retain(|&t| t != sch.seq);
            self.chaos_tokens.retain(|&t| t != sch.seq);
            self.clock.advance_to(sch.at);
            self.dispatch(sch.event)?;
            if self.finished {
                break;
            }
        }
        self.finalize()
    }

    /// Open each traced pool's price-replay chain: one pending event per
    /// pool at a time, each handler scheduling the next point. Offset-0
    /// points were folded into the fleet's initial epochs, so a
    /// constant-price trace schedules nothing and the run stays
    /// byte-identical to the static world.
    fn schedule_price_traces(&mut self) {
        for i in 0..self.fleet.num_pools() {
            let pool = PoolId(i);
            if let Some(first) = self.fleet.price_points(pool).first() {
                let at = SimTime::ZERO + first.offset;
                let token = self
                    .queue
                    .schedule(at, SimEvent::PoolPriceChanged { pool, idx: 0 });
                self.price_tokens.push(token);
            }
        }
    }

    /// Arm the plan's storm instants. Like the market, storms belong to
    /// the run: an instance death must not cancel a future storm.
    fn schedule_storms(&mut self) {
        for idx in 0..self.plan.storms.len() {
            let at = self.plan.storms[idx];
            let token = self.queue.schedule(at, SimEvent::ChaosStorm { idx });
            self.chaos_tokens.push(token);
        }
    }

    // ---------------------------------------------------- event plumbing

    fn schedule(&mut self, at: SimTime, event: SimEvent) {
        let token = self.queue.schedule(at, event);
        self.live_tokens.push(token);
    }

    fn schedule_in(&mut self, delay: SimDuration, event: SimEvent) {
        let now = self.clock.now();
        let token = self.queue.schedule_in(now, delay, event);
        self.live_tokens.push(token);
    }

    /// Drop this run's pending timers (instance death / run end) without
    /// touching anything else that may share the queue.
    fn cancel_pending(&mut self) {
        for token in self.live_tokens.drain(..) {
            self.queue.cancel(token);
        }
        self.notice_token = None;
    }

    fn dispatch(&mut self, event: SimEvent) -> Result<()> {
        match event {
            SimEvent::ReplacementRequested => self.on_replacement_requested(),
            SimEvent::PlacementDecided { pool } => {
                self.on_placement_decided(pool)
            }
            SimEvent::InstanceProvisioned => self.on_instance_provisioned(),
            SimEvent::RestoreDone { report } => self.on_restore_done(report),
            SimEvent::BoundaryReached => self.on_boundary(),
            SimEvent::StepDone => self.on_step_done(),
            SimEvent::CkptDone { periodic, outcome } => {
                self.on_ckpt_done(periodic, outcome)
            }
            SimEvent::NoticePosted => self.on_notice_posted(),
            SimEvent::PollTick => self.on_poll_tick(),
            SimEvent::NoticeDeadline => self.on_instance_reclaimed(),
            SimEvent::TerminationCkptDone { outcome, notice } => {
                self.on_termination_ckpt_done(outcome, notice)
            }
            SimEvent::InstanceEvicted => self.on_instance_reclaimed(),
            SimEvent::PoolPriceChanged { pool, idx } => {
                self.on_price_changed(pool, idx)
            }
            SimEvent::ChaosStorm { idx } => self.on_chaos_storm(idx),
            SimEvent::CkptRetry { periodic, attempt } => {
                self.attempt_ckpt(periodic, attempt)
            }
        }
    }

    // --------------------------------------------------------- handlers

    /// The run needs an instance: consult the placement policy. The
    /// decision itself is instantaneous (it happens at the eviction
    /// instant); the pool's provisioning delay is paid between the
    /// decision and `InstanceProvisioned`.
    fn on_replacement_requested(&mut self) -> Result<()> {
        let now = self.clock.now();
        let views = self.fleet.views();
        let pool = self.placement.place(self.fleet.active_pool(), &views);
        if self.fleet.is_multi_pool() {
            self.timeline.record_with(now, EventKind::ReplacementRequested, || {
                format!("placement via {}", self.placement.name())
            });
        }
        self.schedule(now, SimEvent::PlacementDecided { pool });
        Ok(())
    }

    /// The pool is chosen: start provisioning there.
    fn on_placement_decided(&mut self, pool: PoolId) -> Result<()> {
        let now = self.clock.now();
        self.fleet.set_active(pool)?;
        if self.fleet.is_multi_pool() {
            let views = self.fleet.views();
            let view = &views[pool.0];
            self.timeline.record_with(now, EventKind::PlacementDecided, || {
                format!(
                    "{} ({} {} @ ${:.4}/h)",
                    view.name,
                    view.vm_size,
                    if view.spot { "spot" } else { "on-demand" },
                    view.price_per_hour
                )
            });
        }
        let ready = self.fleet.ready_at(pool, now);
        self.schedule(ready, SimEvent::InstanceProvisioned);
        Ok(())
    }

    /// A fresh instance is Running: record it, derive its eviction
    /// schedule from its pool's plan, and restore from the share
    /// (Spot-on) or start over (unprotected).
    fn on_instance_provisioned(&mut self) -> Result<()> {
        let now = self.clock.now();
        let inst_id = self.fleet.launch(now).id.to_string();
        self.controller.observe_launch(self.fleet.active_pool(), now);
        self.timeline.record_with(now, EventKind::InstanceLaunch, || {
            if self.fleet.is_multi_pool() {
                format!(
                    "{inst_id} in {}",
                    self.fleet.pool_name(self.fleet.active_pool())
                )
            } else {
                inst_id.clone()
            }
        });
        let mut monitor = ScheduledEventsMonitor::new(&inst_id);
        monitor.reset();
        self.monitor = Some(monitor);

        let spoton = self.spoton;
        let notice = self.cfg.cloud.notice;
        let poll_interval = self.cfg.cloud.poll_interval;
        let schedule = self.fleet.next_eviction_offset().map(|offset| {
            let post = now + offset;
            let deadline = post + notice;
            let detect = if !spoton {
                // no coordinator: nothing detects; death at deadline
                deadline
            } else {
                // first poll tick at/after the post, ticks measured from
                // this instance's start
                let since_start = post.since(now).as_millis();
                let poll = poll_interval.as_millis().max(1);
                let ticks = since_start.div_ceil(poll);
                now + SimDuration::from_millis(ticks * poll)
            };
            EvictionSchedule { post, detect, deadline }
        });
        self.inst = Some(InstanceCtx {
            id: inst_id,
            schedule,
            started: now,
            bid: self.fleet.pool_bid(self.fleet.active_pool()),
            outbid_at: None,
        });
        // a replacement can land in a pool whose price rose past the
        // configured bid since fleet validation: the instance is born
        // outbid — the Preempt posts immediately and nothing past the
        // launch instant is billed
        self.check_outbid(self.fleet.active_pool(), now);

        if self.spoton {
            // Fallback search: a committed generation that fails
            // verification (chaos corruption) is skipped — recorded as a
            // fallback — and the next-newest verified one restores. With
            // chaos off every committed generation verifies, so this is
            // exactly the classic most-recent-valid lookup.
            let search = RestartManager::find_and_restore_with_fallback(
                &mut self.store,
                &self.policy,
                self.workload.as_mut(),
            )
            .context("restart")?;
            for (id, problem) in &search.skipped {
                self.timeline.record_with(
                    now,
                    EventKind::RestoreFallback,
                    || format!("ckpt {id} unusable ({problem})"),
                );
            }
            match search.report {
                Some(report) => {
                    let cost = report.cost;
                    self.schedule_in(cost, SimEvent::RestoreDone { report });
                    return Ok(());
                }
                None => {
                    if !search.skipped.is_empty() {
                        self.timeline.record(
                            now,
                            EventKind::UnrecoveredRestore,
                            "every committed generation failed verification",
                        );
                    }
                    if self.evictions > 0 {
                        // unprotected restart: begin from scratch
                        self.workload = (self.factory)()?;
                        self.lost_steps += self.max_steps_seen;
                    }
                }
            }
        } else if self.evictions > 0 {
            self.workload = (self.factory)()?;
            self.lost_steps += self.max_steps_seen;
        }

        self.last_ckpt_at = now;
        self.schedule(now, SimEvent::BoundaryReached);
        Ok(())
    }

    fn on_restore_done(&mut self, report: RestoreReport) -> Result<()> {
        let now = self.clock.now();
        self.restores += 1;
        self.controller.observe_restore(now);
        self.lost_steps += self
            .max_steps_seen
            .saturating_sub(report.resumed_total_steps);
        self.timeline.record_with(now, EventKind::RestoreFromCheckpoint, || {
            format!(
                "ckpt {} ({}) -> step {}",
                report.manifest.id,
                report.manifest.kind.as_str(),
                report.resumed_total_steps
            )
        });
        self.last_ckpt_at = now;
        self.schedule(now, SimEvent::BoundaryReached);
        Ok(())
    }

    /// Step boundary: abort on scenario deadline, else take a due periodic
    /// checkpoint, else either begin the eviction reaction (if the notice
    /// interrupts the upcoming step) or run the step.
    fn on_boundary(&mut self) -> Result<()> {
        let now = self.clock.now();
        if now.since(SimTime::ZERO) >= self.cfg.deadline {
            let reason = format!("deadline {} exceeded", self.cfg.deadline);
            self.terminate_current_billed(now);
            self.timeline
                .record(now, EventKind::Aborted, reason.clone());
            self.aborted_reason = Some(reason);
            self.finish();
            return Ok(());
        }

        // periodic transparent checkpoint at step boundary (the snapshot
        // buffer is reused across every checkpoint of the run)
        if self.spoton && self.periodic_due(now) {
            return self.attempt_ckpt(true, 0);
        }

        self.decide_step()
    }

    /// One checkpoint write attempt — periodic boundary capture
    /// (`periodic`) or application milestone — with chaos-aware failure
    /// handling: an injected storage fault burns the virtual time the
    /// transfer consumed and, while the retry policy has attempts left,
    /// schedules a [`SimEvent::CkptRetry`] after the backoff delay
    /// instead of failing the run.
    fn attempt_ckpt(&mut self, periodic: bool, attempt: u32) -> Result<()> {
        let now = self.clock.now();
        let kind =
            if periodic { CkptKind::Periodic } else { CkptKind::AppNative };
        if periodic {
            self.workload.snapshot_into(&mut self.snap_buf)?;
        } else {
            match self.workload.app_snapshot()? {
                Some(snap) => self.snap_buf = snap,
                // nothing to capture at this milestone — back to the
                // boundary (also covers a retry outliving its milestone)
                None => {
                    self.schedule(now, SimEvent::BoundaryReached);
                    return Ok(());
                }
            }
        }
        let res = self.writer.write(
            &mut self.store,
            now,
            kind,
            self.workload.as_ref(),
            &self.snap_buf,
        );
        match res {
            Ok(outcome) => {
                self.drain_faults(now);
                let cost = outcome.cost(); // workload frozen while dumping
                self.schedule_in(cost, SimEvent::CkptDone {
                    periodic,
                    outcome,
                });
                Ok(())
            }
            Err(e) => match e.downcast_ref::<InjectedFault>() {
                Some(fault) => {
                    let burned = fault.burned;
                    self.drain_faults(now);
                    self.on_ckpt_fault(periodic, attempt, burned)
                }
                None => Err(e),
            },
        }
    }

    /// A checkpoint write died on an injected storage fault: retry under
    /// the backoff policy, or surrender the generation and move on — a
    /// lost generation is a wider eviction-rollback window, not a dead
    /// run.
    fn on_ckpt_fault(
        &mut self,
        periodic: bool,
        attempt: u32,
        burned: SimDuration,
    ) -> Result<()> {
        let now = self.clock.now();
        let label = if periodic { "periodic" } else { "application" };
        let can_retry = self
            .backoff
            .as_ref()
            .map_or(false, |b| b.retries_left(attempt));
        if can_retry {
            let delay = self
                .backoff
                .as_mut()
                // spoton-lint: allow(D3, reason = "retry policies are constructed with a backoff")
                .expect("retries imply a backoff policy")
                .delay(attempt);
            self.timeline.record_with(now, EventKind::CkptRetried, || {
                format!(
                    "{label} ckpt attempt {} failed; retry in {delay}",
                    attempt + 1
                )
            });
            self.schedule_in(burned + delay, SimEvent::CkptRetry {
                periodic,
                attempt: attempt + 1,
            });
        } else {
            self.timeline.record_with(now, EventKind::CheckpointFailed, || {
                format!(
                    "{label} ckpt failed after {} attempt(s); \
                     generation lost",
                    attempt + 1
                )
            });
            if periodic {
                // the cadence clock still advances: the next due test
                // starts from the failure, not the last success
                self.last_ckpt_at = now;
            }
            self.schedule_in(burned, SimEvent::BoundaryReached);
        }
        Ok(())
    }

    /// Surface the chaos wrapper's injected-fault log onto the timeline.
    fn drain_faults(&mut self, now: SimTime) {
        for f in self.store.take_faults() {
            let kind = match f.kind {
                FaultKind::WriteFail => EventKind::ChaosWriteFault,
                FaultKind::TornWrite => EventKind::ChaosTornWrite,
                FaultKind::Corrupt => EventKind::ChaosCorruption,
                FaultKind::LatencySpike => EventKind::ChaosLatencySpike,
            };
            self.timeline.record(now, kind, f.key);
        }
    }

    /// Is a periodic checkpoint due at this boundary? The interval
    /// controller decides: it sees the configured interval, the modeled
    /// checkpoint cost, and the active pool's current price factor, and
    /// answers with the gap the due test should use. A `FixedInterval`
    /// controller always answers `base_interval`, making this exactly
    /// `CheckpointPolicy::periodic_due` — the legacy-equivalence pin.
    fn periodic_due(&mut self, now: SimTime) -> bool {
        let Some(base) = self.policy.periodic_interval() else {
            return false;
        };
        let pool = self.fleet.active_pool();
        let ctx = PolicyCtx {
            now,
            last_ckpt: self.last_ckpt_at,
            base_interval: base,
            ckpt_cost: self.ckpt_cost_est,
            pool,
            price_factor: self.fleet.price_factor(pool),
        };
        now.since(self.last_ckpt_at) >= self.controller.next_interval(&ctx)
    }

    /// Commit to the next step — or, when the posted notice / reclaim
    /// instant falls inside it, begin the eviction reaction instead.
    fn decide_step(&mut self) -> Result<()> {
        let now = self.clock.now();

        // next step's virtual cost
        let stage = self.workload.progress().stage as usize;
        let step_cost = SimDuration::from_secs_f64(
            self.cfg.workload.stage_secs[stage] as f64
                / self.workload.stage_steps(stage as u32) as f64
                * self.overhead_factor,
        );

        // does the eviction interrupt before this step finishes?
        if let Some(es) =
            self.inst.as_ref().and_then(|inst| inst.schedule)
        {
            let step_end = now + step_cost;
            if es.detect <= step_end || es.deadline <= step_end {
                // the platform's post becomes visible no earlier than the
                // boundary that observes it (legacy-loop semantics)
                let post_visible = es.post.max(now);
                let token =
                    self.queue.schedule(post_visible, SimEvent::NoticePosted);
                self.live_tokens.push(token);
                // remembered so a storm can pull the post forward
                self.notice_token = Some(token);
                return Ok(());
            }
        }

        self.schedule_in(step_cost, SimEvent::StepDone);
        Ok(())
    }

    fn on_step_done(&mut self) -> Result<()> {
        let now = self.clock.now();
        let outcome = self.workload.step()?;
        self.max_steps_seen = self
            .max_steps_seen
            .max(self.workload.progress().total_steps);

        let mut milestone = false;
        match outcome {
            StepOutcome::Advanced => {}
            StepOutcome::Milestone => milestone = true,
            StepOutcome::StageComplete(s) => {
                milestone = true;
                self.completion_at[s as usize] = Some(now);
                self.timeline.record_with(now, EventKind::StageComplete, || {
                    self.workload.stage_label(s)
                });
            }
            StepOutcome::Done => {
                let s = (self.workload.num_stages() - 1) as usize;
                self.completion_at[s] = Some(now);
                self.timeline.record_with(now, EventKind::StageComplete, || {
                    self.workload.stage_label(s as u32)
                });
                self.timeline.record_with(now, EventKind::WorkloadDone, || {
                    format!("{} steps", self.workload.progress().total_steps)
                });
                self.completed = true;
                self.terminate_current_billed(now);
                self.finish();
                return Ok(());
            }
        }

        // application milestone checkpoint (the app writes its own files
        // when app-native checkpointing is enabled)
        if milestone && self.spoton && self.policy.persists_app_milestones() {
            // attempt_ckpt falls back to the boundary itself when the
            // workload has no milestone snapshot to offer
            return self.attempt_ckpt(false, 0);
        }

        self.schedule(now, SimEvent::BoundaryReached);
        Ok(())
    }

    fn on_ckpt_done(
        &mut self,
        periodic: bool,
        outcome: WriteOutcome,
    ) -> Result<()> {
        let now = self.clock.now();
        if periodic {
            // the observed write cost (includes manifest/commit
            // latency) refines the controllers' a-priori δ estimate
            self.controller.observe_ckpt_cost(outcome.cost());
        }
        if let Some(manifest) = outcome.committed() {
            if periodic {
                self.periodic_ckpts += 1;
                self.timeline.record_with(
                    now,
                    EventKind::CheckpointCommitted,
                    || format!("periodic ckpt {}", manifest.id),
                );
            } else {
                self.app_ckpts += 1;
                self.timeline.record_with(
                    now,
                    EventKind::CheckpointCommitted,
                    || format!("application ckpt {}", manifest.id),
                );
            }
        }
        CheckpointStore::gc(&mut self.store, self.cfg.retain as usize)?;
        if periodic {
            self.last_ckpt_at = now;
            // Legacy-loop shape: after a periodic checkpoint the driver
            // proceeded straight to the step decision — the scenario
            // deadline is only re-checked at the next true boundary.
            self.decide_step()
        } else {
            // An application-milestone checkpoint ended the iteration:
            // back to the full boundary (deadline + periodic checks).
            self.schedule(now, SimEvent::BoundaryReached);
            Ok(())
        }
    }

    /// The Preempt hits the metadata service. Route to the coordinator's
    /// poll tick, or — when nothing will react in time — straight to the
    /// reclaim deadline.
    fn on_notice_posted(&mut self) -> Result<()> {
        let now = self.clock.now();
        self.notice_token = None;
        let (inst_id, es) = {
            let inst = self
                .inst
                .as_ref()
                // spoton-lint: allow(D3, reason = "event-queue invariant: events only target live instances")
                .expect("notice events require a live instance");
            (
                inst.id.clone(),
                // spoton-lint: allow(D3, reason = "eviction events are only scheduled with a schedule set")
                inst.schedule.expect("notice without an eviction schedule"),
            )
        };
        let detail = self.metadata.post_preempt(&inst_id, es.deadline);
        self.timeline.record(now, EventKind::EvictionNotice, detail);
        self.notices += 1;

        if !self.spoton || es.detect >= es.deadline {
            // nobody reacts in time: death at deadline
            self.schedule(es.deadline.max(now), SimEvent::NoticeDeadline);
        } else {
            self.schedule(es.detect.max(now), SimEvent::PollTick);
        }
        Ok(())
    }

    /// The coordinator's poll tick surfaces the notice; its reaction
    /// (termination-checkpoint race or immediate ack) lives in
    /// [`crate::coordinator::handlers`].
    fn on_poll_tick(&mut self) -> Result<()> {
        let now = self.clock.now();
        let es = self
            .inst
            .as_ref()
            .and_then(|inst| inst.schedule)
            // spoton-lint: allow(D3, reason = "eviction events are only scheduled with a schedule set")
            .expect("poll tick without an eviction schedule");
        if self.plan.imds_down(now) {
            // IMDS outage: this poll sees nothing. The monitor degrades
            // to a slower cadence and keeps polling; if even the
            // degraded tick cannot land before the reclaim instant, the
            // notice goes unobserved and the platform simply kills the
            // instance at the deadline — degraded, accounted, never
            // wedged.
            if !self.imds_was_down {
                self.imds_was_down = true;
                self.metadata.set_available(false);
                self.timeline.record_with(now, EventKind::ImdsOutage, || {
                    match self.plan.outage_ends(now) {
                        Some(end) => format!(
                            "scheduled-events endpoint down until {end}"
                        ),
                        None => "scheduled-events endpoint down".into(),
                    }
                });
            }
            let degraded =
                self.plan.degraded_poll(self.cfg.cloud.poll_interval);
            self.timeline.record_with(now, EventKind::PollDegraded, || {
                format!("poll backed off to {degraded}")
            });
            let next = now + degraded;
            if next < es.deadline {
                self.schedule(next, SimEvent::PollTick);
            } else {
                self.schedule(es.deadline.max(now), SimEvent::NoticeDeadline);
            }
            return Ok(());
        }
        if self.imds_was_down {
            self.imds_was_down = false;
            self.metadata.set_available(true);
        }
        let reaction = handlers::on_poll_tick(
            // spoton-lint: allow(D3, reason = "live instances always carry a monitor")
            self.monitor.as_mut().expect("live instance has a monitor"),
            &mut self.metadata,
            &self.policy,
            &mut self.writer,
            &mut self.store,
            self.workload.as_ref(),
            now,
            es.deadline,
        )?;
        self.drain_faults(now);
        match reaction {
            PollReaction::TerminationCkpt { notice, outcome } => {
                let cost = outcome.cost();
                self.schedule_in(cost, SimEvent::TerminationCkptDone {
                    outcome,
                    notice,
                });
            }
            PollReaction::AckOnly => {
                self.schedule(now, SimEvent::InstanceEvicted);
            }
        }
        Ok(())
    }

    fn on_termination_ckpt_done(
        &mut self,
        outcome: WriteOutcome,
        notice: Notice,
    ) -> Result<()> {
        let now = self.clock.now();
        if let Some(manifest) = outcome.committed() {
            self.termination_ok += 1;
            self.timeline.record_with(
                now,
                EventKind::CheckpointCommitted,
                || format!("termination ckpt {}", manifest.id),
            );
        } else {
            self.termination_failed += 1;
            self.timeline.record(
                now,
                EventKind::CheckpointFailed,
                "termination ckpt missed deadline",
            );
        }
        handlers::ack_notice(
            // spoton-lint: allow(D3, reason = "live instances always carry a monitor")
            self.monitor.as_ref().expect("live instance has a monitor"),
            &mut self.metadata,
            &notice,
        );
        self.schedule(now, SimEvent::InstanceEvicted);
        Ok(())
    }

    /// The instance dies (notice expiry or post-checkpoint reclaim): bill
    /// its uptime against its pool, record the eviction as placement
    /// evidence, drop its pending timers, and open the replacement chain.
    fn on_instance_reclaimed(&mut self) -> Result<()> {
        let now = self.clock.now();
        let terminated = self.terminate_current_billed(now);
        let inst = self
            .inst
            .take()
            // spoton-lint: allow(D3, reason = "event-queue invariant: events only target live instances")
            .expect("reclaim events require a live instance");
        if let Some((_, pool)) = terminated {
            self.fleet.note_eviction(pool);
            self.controller.observe_eviction(pool, now);
        }
        self.metadata.clear_resource(&inst.id);
        self.evictions += 1;
        self.timeline
            .record(now, EventKind::InstanceEvicted, inst.id);
        // the dead instance's timers die with it — cancel by token, never
        // clear(): other runs may share this queue
        self.cancel_pending();
        self.schedule(now, SimEvent::ReplacementRequested);
        Ok(())
    }

    /// A traced pool's price moved: open a new billing epoch (placement
    /// sees the new price at the next replacement; the live instance's
    /// uptime is split here when it terminates) and schedule the trace's
    /// next point.
    fn on_price_changed(&mut self, pool: PoolId, idx: usize) -> Result<()> {
        let now = self.clock.now();
        let (point, next) = {
            let points = self.fleet.price_points(pool);
            (points[idx], points.get(idx + 1).copied())
        };
        let (old, new) = self.fleet.apply_price_factor(pool, point.factor, now);
        self.controller.observe_price(pool, point.factor);
        self.timeline.record_with(now, EventKind::PoolPriceChanged, || {
            format!(
                "{}: ${old:.4}/h -> ${new:.4}/h (x{})",
                self.fleet.pool_name(pool),
                point.factor
            )
        });
        if let Some(next) = next {
            let token = self.queue.schedule(
                SimTime::ZERO + next.offset,
                SimEvent::PoolPriceChanged { pool, idx: idx + 1 },
            );
            self.price_tokens.push(token);
        }
        self.check_outbid(pool, now);
        Ok(())
    }

    /// Did a price move (or a fresh launch) carry `pool` past the live
    /// instance's bid? If so the market outbids it: billing stops at the
    /// crossing, and the Preempt posts *now* — the configured notice
    /// window still runs before the reclaim, exactly like a chaos storm
    /// pulling an eviction forward. An eviction already in flight keeps
    /// its schedule; the crossing still clamps billing.
    fn check_outbid(&mut self, pool: PoolId, now: SimTime) {
        if pool != self.fleet.active_pool() {
            return;
        }
        let Some(inst) = self.inst.as_ref() else { return };
        let Some(bid) = inst.bid else { return };
        if inst.outbid_at.is_some() {
            return;
        }
        let price = self.fleet.pool_price(pool);
        if price <= bid {
            return;
        }
        let started = inst.started;
        let already_posted = inst.schedule.map_or(false, |es| es.post <= now);
        if let Some(i) = self.inst.as_mut() {
            i.outbid_at = Some(now);
        }
        self.timeline.record_with(now, EventKind::PoolOutbid, || {
            format!(
                "{}: price ${price:.4}/h crossed bid ${bid:.4}/h",
                self.fleet.pool_name(pool)
            )
        });
        if already_posted {
            return;
        }
        let post = now;
        let deadline = post + self.cfg.cloud.notice;
        let detect = if !self.spoton {
            deadline
        } else {
            // first poll tick at/after the post, ticks measured from the
            // instance's launch — same rule as the planned schedule
            let since_start = post.since(started).as_millis();
            let poll = self.cfg.cloud.poll_interval.as_millis().max(1);
            let ticks = since_start.div_ceil(poll);
            started + SimDuration::from_millis(ticks * poll)
        };
        if let Some(i) = self.inst.as_mut() {
            i.schedule = Some(EvictionSchedule { post, detect, deadline });
        }
        // a boundary already committed to the (later) planned post:
        // pull that pending NoticePosted forward to now
        if let Some(token) = self.notice_token.take() {
            self.queue.cancel(token);
            self.live_tokens.retain(|&t| t != token);
            let new_token = self.queue.schedule(now, SimEvent::NoticePosted);
            self.live_tokens.push(new_token);
            self.notice_token = Some(new_token);
        }
    }

    /// Terminate the live instance, billing to the outbid crossing when
    /// the market reclaimed the capacity first.
    fn terminate_current_billed(
        &mut self,
        now: SimTime,
    ) -> Option<(crate::cloud::instance::InstanceId, PoolId)> {
        match self.inst.as_ref().and_then(|i| i.outbid_at) {
            Some(at) => {
                self.fleet.terminate_current_outbid(now, at, &mut self.billing)
            }
            None => self.fleet.terminate_current(now, &mut self.billing),
        }
    }

    /// A planned eviction storm lands: rewrite the live instance's
    /// eviction schedule so the Preempt posts *now* (the platform still
    /// grants the configured notice before reclaiming). A run with no
    /// live instance — provisioning, or between instances — rides the
    /// storm out: storms hit instances, not queued work.
    fn on_chaos_storm(&mut self, idx: usize) -> Result<()> {
        let now = self.clock.now();
        let started = match &self.inst {
            Some(inst) => inst.started,
            None => {
                self.timeline.record_with(now, EventKind::ChaosStorm, || {
                    format!("storm {idx}: no live instance")
                });
                return Ok(());
            }
        };
        let already_posted = self
            .inst
            .as_ref()
            .and_then(|inst| inst.schedule)
            .map_or(false, |es| es.post <= now);
        if already_posted {
            self.timeline.record_with(now, EventKind::ChaosStorm, || {
                format!("storm {idx}: eviction already in flight")
            });
            return Ok(());
        }
        let post = now;
        let deadline = post + self.cfg.cloud.notice;
        let detect = if !self.spoton {
            deadline
        } else {
            // first poll tick at/after the post, ticks measured from the
            // instance's launch — same rule as the planned schedule
            let since_start = post.since(started).as_millis();
            let poll = self.cfg.cloud.poll_interval.as_millis().max(1);
            let ticks = since_start.div_ceil(poll);
            started + SimDuration::from_millis(ticks * poll)
        };
        if let Some(inst) = self.inst.as_mut() {
            inst.schedule = Some(EvictionSchedule { post, detect, deadline });
        }
        // if the boundary already committed to the (later) planned post,
        // pull that pending NoticePosted forward to now
        if let Some(token) = self.notice_token.take() {
            self.queue.cancel(token);
            self.live_tokens.retain(|&t| t != token);
            let new_token = self.queue.schedule(now, SimEvent::NoticePosted);
            self.live_tokens.push(new_token);
            self.notice_token = Some(new_token);
        }
        self.timeline.record_with(now, EventKind::ChaosStorm, || {
            format!("storm {idx}: eviction rescheduled to now")
        });
        Ok(())
    }

    // ------------------------------------------------------- run ending

    fn finish(&mut self) {
        self.finished = true;
        self.cancel_pending();
        // un-replayed market moves and un-landed storms die with the run
        for token in self.price_tokens.drain(..) {
            self.queue.cancel(token);
        }
        for token in self.chaos_tokens.drain(..) {
            self.queue.cancel(token);
        }
    }

    fn finalize(mut self) -> Result<RunResult> {
        // ---- storage billing over the whole run ----
        let total = self.clock.now().since(SimTime::ZERO);
        if self.spoton && self.policy.protected() {
            self.billing.book_storage(
                "nfs-share",
                self.cfg.storage.provisioned_gib,
                total,
                self.cfg.storage.price_per_100gib_month,
            );
        }

        // ---- stage durations from final completion times ----
        let mut stage_times = Vec::new();
        let mut prev = SimTime::ZERO;
        for (i, at) in self.completion_at.iter().enumerate() {
            if let Some(t) = at {
                stage_times.push((
                    self.workload.stage_label(i as u32),
                    t.since(prev),
                ));
                prev = *t;
            }
        }

        if let Some(reason) = &self.aborted_reason {
            log::warn!("{}: {reason}", self.cfg.name);
        }

        // deadline-SLA verdict (observational — `[job] deadline_mins`
        // never changes the run, only judges it): a job that never
        // completed cannot have met its deadline
        let completed = self.completed;
        let deadline_missed = self.cfg.job_deadline.map(|d| {
            let missed = !completed || total > d;
            if missed {
                self.timeline.record_with(
                    self.clock.now(),
                    EventKind::DeadlineMissed,
                    || {
                        if completed {
                            format!("finished at {total}, deadline {d}")
                        } else {
                            format!("did not finish; deadline {d}")
                        }
                    },
                );
            }
            missed
        });

        Ok(RunResult {
            scenario: self.cfg.name.clone(),
            completed: self.completed,
            stage_times,
            total,
            notices: self.notices,
            evictions: self.evictions,
            instances: self.fleet.total_launched(),
            periodic_ckpts: self.periodic_ckpts,
            termination_ok: self.termination_ok,
            termination_failed: self.termination_failed,
            app_ckpts: self.app_ckpts,
            restores: self.restores,
            lost_steps: self.lost_steps,
            compute_cost: self.billing.compute_total(),
            storage_cost: self.billing.storage_total(),
            invoice: self.billing.invoice(),
            pool_stats: self.fleet.stats(&self.billing),
            timeline: self.timeline,
            final_fingerprint: self.workload.fingerprint(),
            deadline_missed,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::experiment::Experiment;

    #[test]
    fn engine_smoke_row5() {
        // Full engine path through the public facade: Table I row 5.
        let r = Experiment::table1()
            .eviction_every(SimDuration::from_mins(90))
            .transparent(SimDuration::from_mins(30))
            .run_sleeper()
            .unwrap();
        assert!(r.completed);
        assert_eq!(r.evictions, 2);
        assert_eq!(r.instances, 3);
        assert!(r.timeline.is_monotone());
        // the default fleet is a single pool carrying the whole run
        assert_eq!(r.pool_stats.len(), 1);
        assert_eq!(r.pool_stats[0].launches, 3);
        assert_eq!(r.pool_stats[0].evictions, 2);
        assert!(
            (r.pool_stats[0].compute_cost - r.compute_cost).abs() < 1e-12
        );
    }

    #[test]
    fn traced_market_flips_cheapest_spot_mid_run() {
        use crate::cloud::trace::{PricePoint, PriceTrace};
        use crate::config::{
            EvictionPlanCfg, PlacementPolicyCfg, PoolCfg, PoolPricingCfg,
        };
        // "spiky" starts 20% cheap but the market spikes at the 60-minute
        // mark; "steady" holds the catalog price. CheapestSpot rides
        // spiky until an eviction lands after the spike, then flips.
        let spike = PriceTrace::new(vec![
            PricePoint { offset: SimDuration::ZERO, factor: 0.8 },
            PricePoint { offset: SimDuration::from_mins(60), factor: 1.8 },
        ])
        .unwrap();
        let r = Experiment::table1()
            .named("flip")
            .transparent(SimDuration::from_mins(15))
            .pool(
                PoolCfg::named("spiky")
                    .pricing(PoolPricingCfg::Trace(spike))
                    .eviction(EvictionPlanCfg::Fixed {
                        interval: SimDuration::from_mins(40),
                    }),
            )
            .pool(PoolCfg::named("steady"))
            .placement(PlacementPolicyCfg::CheapestSpot)
            .run_sleeper()
            .unwrap();
        assert!(r.completed, "{}", r.summary());
        assert_eq!(
            r.timeline.count(crate::metrics::EventKind::PoolPriceChanged),
            1
        );
        let placements: Vec<&str> = r
            .timeline
            .events()
            .iter()
            .filter(|e| {
                e.kind == crate::metrics::EventKind::PlacementDecided
            })
            .map(|e| e.detail.as_ref())
            .collect();
        assert!(placements.len() >= 3, "placements: {placements:?}");
        assert!(
            placements.first().unwrap().contains("spiky"),
            "first placement chases the discount: {placements:?}"
        );
        assert!(
            placements.last().unwrap().contains("steady"),
            "post-spike placement flips pools: {placements:?}"
        );
        // the instance straddling the spike was billed per price segment
        let vm_items = r
            .invoice
            .items
            .iter()
            .filter(|i| i.resource.starts_with("vm/"))
            .count();
        assert!(
            vm_items > r.instances as usize,
            "expected a straddling instance to book >1 segment \
             ({vm_items} items for {} instances)",
            r.instances
        );
        // attribution still partitions the compute total
        let attributed: f64 =
            r.pool_stats.iter().map(|p| p.compute_cost).sum();
        assert!((attributed - r.compute_cost).abs() < 1e-9);
    }

    #[test]
    fn adaptive_controller_requires_transparent_method() {
        use crate::config::IntervalControllerCfg;
        // The builder API enforces the same pairing rule the
        // [checkpoint.adaptive] parser does: an adaptive controller on a
        // run with no periodic interval is an error, not a silent no-op.
        let err = Experiment::table1()
            .named("adaptive-mismatch")
            .app_native()
            .adaptive(IntervalControllerCfg::young_daly())
            .run_sleeper()
            .unwrap_err();
        assert!(err.to_string().contains("transparent"), "{err}");
        // Fixed (the identity) stays valid with every method
        assert!(Experiment::table1()
            .adaptive(IntervalControllerCfg::Fixed)
            .run_sleeper()
            .is_ok());
    }

    #[test]
    fn young_daly_controller_tightens_cadence_under_a_storm() {
        use crate::config::IntervalControllerCfg;
        // Same storm, same 30-minute configured interval, and a 10 s
        // notice the 3 GiB image can never beat (termination checkpoints
        // all fail — periodic cadence is the only protection): evictions
        // at 40 min of uptime cost the fixed policy the 10 min since its
        // last 30-min checkpoint, while Young/Daly (δ ≈ 12 s, MTBF
        // estimate collapsing toward 40 min) tightens to a few minutes
        // and loses far less per eviction.
        let storm = |cfg: IntervalControllerCfg| {
            Experiment::table1()
                .named("adaptive-smoke")
                .eviction_every(SimDuration::from_mins(40))
                .transparent(SimDuration::from_mins(30))
                .notice(SimDuration::from_secs(10))
                .deadline(SimDuration::from_hours(30))
                .adaptive(cfg)
                .run_sleeper()
                .unwrap()
        };
        let fixed = storm(IntervalControllerCfg::Fixed);
        let adaptive = storm(IntervalControllerCfg::young_daly());
        assert!(fixed.completed && adaptive.completed);
        assert!(
            adaptive.periodic_ckpts > fixed.periodic_ckpts,
            "young-daly {} ckpts vs fixed {}",
            adaptive.periodic_ckpts,
            fixed.periodic_ckpts
        );
        assert!(
            adaptive.lost_steps < fixed.lost_steps,
            "young-daly lost {} steps vs fixed {}",
            adaptive.lost_steps,
            fixed.lost_steps
        );
        // both still land the same final state
        assert_eq!(adaptive.final_fingerprint, fixed.final_fingerprint);
    }

    #[test]
    fn engine_leaves_no_dangling_events() {
        // After a completed run every scheduled token was either popped or
        // cancelled — the queue the engine leaves behind is empty.
        let exp = Experiment::table1()
            .eviction_every(SimDuration::from_mins(60))
            .transparent(SimDuration::from_mins(15));
        let mut store = crate::storage::BlobStore::for_tests();
        let mut factory = exp.sleeper_factory();
        let mut engine =
            Engine::new(&exp.cfg, &mut store, &mut *factory).unwrap();
        engine.writer.resume_after(None);
        engine
            .queue
            .schedule(SimTime::ZERO, SimEvent::ReplacementRequested);
        loop {
            let Some(sch) = engine.queue.pop() else { break };
            engine.live_tokens.retain(|&t| t != sch.seq);
            engine.clock.advance_to(sch.at);
            engine.dispatch(sch.event).unwrap();
            if engine.finished {
                break;
            }
        }
        assert!(engine.finished);
        assert!(engine.queue.is_empty(), "stale events left behind");
        assert!(engine.live_tokens.is_empty());
    }
}
