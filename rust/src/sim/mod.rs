//! Virtual-time experiment harness.
//!
//! The core is a discrete-event engine ([`engine`]): every run is a chain
//! of typed [`engine::SimEvent`]s — step completions, checkpoint commits,
//! eviction notices, poll ticks, provisioning completions — on the
//! deterministic `simclock::EventQueue`. The workload really computes
//! (PJRT for MiniMeta) while its time is charged virtually, calibrated so
//! an uninterrupted run reproduces the paper's Table I row-1 stage
//! durations (DESIGN.md §6).
//!
//! * [`driver`] — the stable facade ([`SimDriver`], [`RunResult`]) every
//!   bench, test and example drives.
//! * [`engine`] — the event loop + per-concern handlers.
//! * [`legacy`] — the pre-refactor imperative loop, frozen as the oracle
//!   for `tests/engine_equivalence.rs`.
//! * [`experiment`] — the builder/preset layer:
//!   `Experiment::table1().eviction_every(90 min).transparent(30 min)` is
//!   the paper's Table I row 5.

pub mod driver;
pub mod engine;
pub mod experiment;
pub mod legacy;

pub use driver::{RunResult, SimDriver};
pub use engine::SimEvent;
pub use experiment::Experiment;
