//! Virtual-time experiment harness.
//!
//! [`driver`] runs one scenario end to end on the discrete-event clock:
//! the workload really computes (PJRT for MiniMeta), while eviction
//! notices, checkpoint transfers, instance provisioning and billing are
//! charged in virtual time calibrated so an uninterrupted run reproduces
//! the paper's Table I row-1 stage durations (DESIGN.md §6).
//!
//! [`experiment`] is the builder/preset layer the benches and examples
//! use: `Experiment::table1().eviction_every(90 min).transparent(30 min)`
//! is the paper's Table I row 5.

pub mod driver;
pub mod experiment;

pub use driver::{RunResult, SimDriver};
pub use experiment::Experiment;
