//! Virtual-time experiment harness.
//!
//! The core is a discrete-event engine ([`engine`]): every run is a chain
//! of typed [`engine::SimEvent`]s — step completions, checkpoint commits,
//! eviction notices, poll ticks, placement decisions, provisioning
//! completions — on the deterministic `simclock::EventQueue`. The
//! workload really computes (PJRT for MiniMeta) while its time is charged
//! virtually, calibrated so an uninterrupted run reproduces the paper's
//! Table I row-1 stage durations (DESIGN.md §6).
//!
//! * [`SimDriver`] / [`RunResult`] (this module) — the stable facade
//!   every bench, test and example drives.
//! * [`engine`] — the event loop + per-concern handlers, running each
//!   scenario on a [`crate::cloud::fleet::Fleet`] of replacement pools.
//! * [`legacy`] — the pre-refactor imperative loop, frozen as the oracle
//!   for `tests/engine_equivalence.rs`.
//! * [`experiment`] — the builder/preset layer:
//!   `Experiment::table1().eviction_every(90 min).transparent(30 min)` is
//!   the paper's Table I row 5.
//! * [`sweep`] — the parallel Monte Carlo driver: thousands of seeded
//!   runs fanned across threads, merged deterministically by seed, fed
//!   into [`crate::report::distribution`] summaries.
//! * [`cluster`] — the multiplexed cluster engine: thousands of
//!   concurrent jobs interleaved as subject-tagged events on **one**
//!   queue around **one** capacity-bounded fleet, with FIFO-per-priority
//!   admission when pools are full; throughput measured in events/sec
//!   (`benches/perf_cluster.rs`).
//! * [`chaos`] — seeded fault injection: the per-run
//!   [`chaos::FaultPlan`] (storm instants, IMDS outage windows) drawn
//!   from `(scenario seed, chaos salt)` only, so chaos-enabled sweeps
//!   stay byte-identical at any parallelism.
//! * [`shard`] — the multi-process sweep runner behind
//!   `spoton sweep`: a [`shard::ShardPlan`] deterministically partitions
//!   seed range × configuration matrix into shards, worker processes
//!   write rename-atomic per-shard artifacts, a checkpointed manifest
//!   makes interrupted sweeps resumable, and the merger folds artifacts
//!   by shard id into byte-identical digests at any process count
//!   (`benches/perf_shards.rs`).
//!
//! ## Time accounting
//!
//! * compute: each workload step costs
//!   `stage_secs[stage] / stage_steps(stage)` virtual seconds, scaled by
//!   `1 + coordinator_overhead` when Spot-on is attached (Table I rows
//!   1→2 delta);
//! * checkpoints: the workload freezes for the modeled transfer time of
//!   the snapshot's charged size (CRIU dump / app checkpoint file write);
//! * eviction: the notice posts at the pool plan's uptime offset; the
//!   coordinator detects it at its next scheduled-events poll tick; a
//!   transparent termination checkpoint races `NotBefore`; the instance
//!   dies, the placement policy picks the replacement's pool, the pool
//!   provisions it (a scheduled event, not a blocking wait), the
//!   coordinator restores from the most recent valid checkpoint.

pub mod chaos;
pub mod cluster;
pub mod engine;
pub mod experiment;
pub mod legacy;
pub mod shard;
pub mod sweep;

pub use chaos::FaultPlan;
pub use cluster::{
    ClusterEngine, ClusterResult, ClusterSweep, JobOutcome, SeededClusterRun,
};
pub use engine::SimEvent;
pub use experiment::Experiment;
pub use shard::{
    MergedSweep, SeedStream, ShardPlan, ShardRunner, ShardedOutcome,
};
pub use sweep::{ControllerSweep, SeededRun, Sweep};

use crate::cloud::billing::Invoice;
use crate::cloud::fleet::PoolStats;
use crate::config::ScenarioConfig;
use crate::metrics::Timeline;
use crate::simclock::SimDuration;
use crate::storage::SharedStore;
use crate::workload::Workload;
use anyhow::Result;

/// Everything one run produced.
#[derive(Debug)]
pub struct RunResult {
    pub scenario: String,
    pub completed: bool,
    /// (stage label, wall duration) — final completion times, so re-done
    /// work lands in the stage where it was re-done (what the paper's
    /// per-k columns report).
    pub stage_times: Vec<(String, SimDuration)>,
    pub total: SimDuration,
    pub notices: u32,
    pub evictions: u32,
    pub instances: u32,
    pub periodic_ckpts: u32,
    pub termination_ok: u32,
    pub termination_failed: u32,
    pub app_ckpts: u32,
    pub restores: u32,
    /// Workload steps lost to evictions (re-executed after restore).
    pub lost_steps: u64,
    pub compute_cost: f64,
    pub storage_cost: f64,
    pub invoice: Invoice,
    /// Per-pool launches/evictions/cost attribution (one entry per fleet
    /// pool; empty only for the frozen legacy oracle, which predates the
    /// fleet).
    pub pool_stats: Vec<PoolStats>,
    pub timeline: Timeline,
    pub final_fingerprint: u64,
    /// Deadline-SLA verdict: `None` when the scenario configures no
    /// `[job] deadline_mins` (the field then stays out of digests, so
    /// deadline-free runs keep their pre-SLA digests byte for byte);
    /// `Some(true)` when the job finished — or aborted — past its
    /// deadline.
    pub deadline_missed: Option<bool>,
}

impl RunResult {
    /// One-line summary.
    pub fn summary(&self) -> String {
        format!(
            "{}: {} in {} | {} eviction(s), {} instance(s), ckpts: {}p/{}t(+{}f)/{}a, \
             {} restore(s), {} steps lost | compute {} + storage {}",
            self.scenario,
            if self.completed { "completed" } else { "DID NOT FINISH" },
            self.total,
            self.evictions,
            self.instances,
            self.periodic_ckpts,
            self.termination_ok,
            self.termination_failed,
            self.app_ckpts,
            self.restores,
            self.lost_steps,
            crate::util::fmt::dollars(self.compute_cost),
            crate::util::fmt::dollars(self.storage_cost),
        )
    }

    pub fn total_cost(&self) -> f64 {
        self.compute_cost + self.storage_cost
    }

    /// Stage duration by label.
    pub fn stage(&self, label: &str) -> Option<SimDuration> {
        self.stage_times
            .iter()
            .find(|(l, _)| l == label)
            .map(|(_, d)| *d)
    }
}

/// The driver: public facade over the event-driven engine. Owns nothing
/// itself; borrows the scenario and the share, and builds a fresh
/// [`engine::Engine`] per run. `factory` builds a fresh workload (used at
/// start and when an unprotected run must restart from zero).
pub struct SimDriver<'a> {
    cfg: &'a ScenarioConfig,
    store: &'a mut dyn SharedStore,
}

impl<'a> SimDriver<'a> {
    pub fn new(cfg: &'a ScenarioConfig, store: &'a mut dyn SharedStore) -> Self {
        Self { cfg, store }
    }

    /// Run the scenario on the event engine.
    pub fn run(
        &mut self,
        factory: &mut dyn FnMut() -> Result<Box<dyn Workload>>,
    ) -> Result<RunResult> {
        engine::Engine::new(self.cfg, self.store, factory)?.run()
    }
}
