//! The multiplexed cluster engine: thousands of concurrent jobs on one
//! contended fleet, interleaved as targeted events on a single queue.
//!
//! The per-run engine ([`super::engine`]) simulates one job on its own
//! event queue; the requeue scheduler ([`crate::sched`]) runs many jobs
//! by building one engine *per attempt*, which serializes the jobs and
//! rebuilds the whole world between attempts. This module multiplexes
//! instead: every job's events carry a job id, live on **one**
//! [`EventQueue`] (subject-tagged — [`EventQueue::schedule_for`] /
//! [`EventQueue::cancel_subject`]), and execute against **one** live
//! [`Fleet`] whose per-pool capacity, eviction draws, price epochs and
//! placement evidence persist across the whole scenario. One `pop` loop
//! drives everything; throughput is reported as sustained events/sec
//! (`benches/perf_cluster.rs` → `BENCH_cluster.json`).
//!
//! ## Admission
//!
//! Pools have **finite capacity** ([`crate::config::PoolCfg::capacity`]).
//! When a job needs an instance (arrival or post-eviction replacement)
//! the placement policy picks a pool as usual; if that pool is full the
//! job does not spin — the cluster timeline records
//! [`EventKind::CapacityExhausted`] then [`EventKind::JobQueued`] and the
//! job parks in a FIFO queue per priority (lower number = higher
//! priority). Every freed slot (eviction, completion, abort) first
//! re-places the head waiter — [`EventKind::JobAdmitted`] — before an
//! evicted job may re-request, so waiters are never starved by churning
//! jobs. The head waiter blocks its queue (strict FIFO): if *its* chosen
//! pool is full, nobody behind it jumps ahead.
//!
//! ## Determinism and equivalence
//!
//! One sequential queue per cluster run: digests are byte-identical at
//! any sweep thread count ([`ClusterSweep`] merges by seed position like
//! [`super::sweep::Sweep`]). Each job carries its *own* store, billing
//! meter, metadata service, checkpoint writer and interval controller, so
//! per-job event-id sequences and invoices never depend on how jobs
//! interleave. A single-job cluster replays the per-run engine **byte for
//! byte** — same placement, launch ids, eviction draws, checkpoint
//! cadence, billing and timeline (`tests/engine_equivalence.rs`); the
//! only deliberate divergences for multi-job runs are documented on
//! [`ClusterEngine::run`].
//!
//! ## Hot path
//!
//! Event routing is O(log queue) per event: the job id on the event
//! indexes straight into the job table — no O(jobs) scan anywhere in the
//! loop. Admission peeks one waiter; placement is O(pools). The rare
//! price-epoch events fan out to every live controller (documented
//! exception, bounded by trace length × jobs).

use super::chaos::FaultPlan;
use super::engine::SimEvent;
use crate::autoscale::{Autoscaler, ScaleDecision, ShiftReason};
use super::experiment::Experiment;
use super::sweep::run_digest;
use super::RunResult;
use crate::checkpoint::{CheckpointStore, CheckpointWriter, CkptKind};
use crate::cloud::billing::BillingMeter;
use crate::cloud::fleet::{
    build_policy, Fleet, PlacementPolicy, PoolId, PoolStats,
};
use crate::cloud::instance::InstanceId;
use crate::cloud::metadata::MetadataService;
use crate::config::{ArrivalCfg, ClusterCfg, ScenarioConfig};
use crate::coordinator::backoff::Backoff;
use crate::coordinator::handlers::{self, PollReaction};
use crate::coordinator::monitor::{Notice, ScheduledEventsMonitor};
use crate::coordinator::policy::CheckpointPolicy;
use crate::coordinator::restart::{RestartManager, RestoreReport};
use crate::metrics::{EventKind, RecordLevel, Timeline};
use crate::policy::{build_controller, IntervalController, PolicyCtx};
use crate::simclock::{Clock, EventQueue, SimDuration, SimTime};
use crate::storage::{
    BlobStore, ChaosStore, FaultKind, InjectedFault, TransferModel,
};
use crate::util::prng::Prng;
use crate::workload::{Snapshot, StepOutcome, Workload};
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Decorrelates Poisson arrival draws from every other consumer of the
/// scenario seed.
const ARRIVAL_SEED_SALT: u64 = 0xCA15_7E2A_0F1E_E7C3;

/// Builds a fresh workload for one job (and rebuilds it after an
/// unprotected restart) — the per-job analogue of the engine's factory.
pub type JobFactory = Box<dyn FnMut() -> Result<Box<dyn Workload>>>;

/// Everything that can happen in a cluster run.
#[derive(Debug)]
pub enum ClusterEvent {
    /// Job `job` enters the system (its arrival-process instant).
    JobArrived { job: usize },
    /// A per-run engine event, targeted at one job.
    Job { job: usize, ev: SimEvent },
    /// The spot market moved — cluster-wide, never owned by a job.
    PoolPriceChanged { pool: PoolId, idx: usize },
    /// A planned eviction storm (chaos) — cluster-wide like the market:
    /// every live instance's eviction schedule is rewritten to post its
    /// Preempt now.
    ChaosStorm { idx: usize },
}

/// When the platform will post/enforce the eviction of one instance
/// (mirror of the per-run engine's schedule).
#[derive(Debug, Clone, Copy)]
struct EvictionSchedule {
    post: SimTime,
    detect: SimTime,
    deadline: SimTime,
}

/// The instance a job currently runs on.
#[derive(Debug)]
struct JobInstance {
    id: String,
    iid: InstanceId,
    pool: PoolId,
    schedule: Option<EvictionSchedule>,
    /// Launch instant — poll ticks are measured from here, so a storm
    /// rewriting the schedule can land `detect` on a real tick boundary.
    started: SimTime,
    /// The maximum hourly price this instance's launch named (the pool's
    /// static bid or the autoscaler's bid-policy bid); `None` launches
    /// can never be outbid.
    bid: Option<f64>,
    /// When a price epoch crossed `bid` — the instant billing stops at,
    /// even though the instance keeps its notice window before reclaim.
    outbid_at: Option<SimTime>,
}

/// One job's complete private world: the same policy / monitor / writer /
/// store / controller pieces a per-run engine owns, so nothing a job does
/// can perturb another job's event-id sequence, checkpoints or invoice.
struct JobState {
    name: String,
    priority: u32,
    factory: JobFactory,
    /// The job's private store behind the chaos wrapper. With `[chaos]`
    /// absent this is a passthrough: pure delegation, no PRNG draws. With
    /// chaos armed each job draws its own fault stream
    /// ([`super::chaos::job_storage_seed`] — job 0's equals the single-run
    /// engine's, the equivalence pin).
    store: ChaosStore<BlobStore>,
    workload: Box<dyn Workload>,
    policy: CheckpointPolicy,
    controller: Box<dyn IntervalController>,
    ckpt_cost_est: SimDuration,
    billing: BillingMeter,
    timeline: Timeline,
    metadata: MetadataService,
    writer: CheckpointWriter,
    monitor: Option<ScheduledEventsMonitor>,
    inst: Option<JobInstance>,
    snap_buf: Snapshot,
    /// Retry policy for this job's failed checkpoint commits
    /// (`[checkpoint.retry]`), with its own jitter stream.
    backoff: Option<Backoff>,
    /// Is this job's monitor currently inside an observed IMDS outage?
    imds_was_down: bool,
    /// Token of this job's pending `NoticePosted`, so a storm can pull an
    /// already decided (but not yet posted) eviction forward to "now".
    notice_token: Option<u64>,
    /// Bid decided at admission, carried until the launch completes (the
    /// autoscaler bids at placement time; the instance exists later).
    pending_bid: Option<f64>,
    /// The job's replacement target (its own "active pool" — placement
    /// stickiness is per job, not cluster-global).
    active: PoolId,
    /// Per-pool (launches, evictions) by this job, for its `PoolStats`.
    pool_counts: Vec<(u32, u32)>,
    launches: u32,
    submitted_at: SimTime,
    admitted_at: Option<SimTime>,
    finished_at: Option<SimTime>,
    last_ckpt_at: SimTime,
    completion_at: Vec<Option<SimTime>>,
    notices: u32,
    evictions: u32,
    periodic_ckpts: u32,
    termination_ok: u32,
    termination_failed: u32,
    app_ckpts: u32,
    restores: u32,
    lost_steps: u64,
    max_steps_seen: u64,
    completed: bool,
    aborted_reason: Option<String>,
    finished: bool,
}

/// One job's outcome: queueing times plus the full per-job [`RunResult`]
/// (so every report that consumes run results works per job unchanged).
#[derive(Debug)]
pub struct JobOutcome {
    pub name: String,
    pub priority: u32,
    pub submitted_at: SimTime,
    /// First admission instant (`None` only for a job that never got a
    /// slot — impossible unless the run was cut short externally).
    pub admitted_at: Option<SimTime>,
    pub finished_at: SimTime,
    pub result: RunResult,
}

impl JobOutcome {
    /// Time spent waiting for the first slot (real queueing delay).
    pub fn wait(&self) -> SimDuration {
        self.admitted_at
            .unwrap_or(self.finished_at)
            .since(self.submitted_at)
    }

    /// Submission-to-finish wall time.
    pub fn turnaround(&self) -> SimDuration {
        self.finished_at.since(self.submitted_at)
    }
}

/// Everything a cluster run produced.
#[derive(Debug)]
pub struct ClusterResult {
    pub scenario: String,
    /// One outcome per configured job, in `[cluster]` job order.
    pub jobs: Vec<JobOutcome>,
    /// Cluster-wide admission timeline (`JobSubmitted`, `JobQueued`,
    /// `JobAdmitted`, `CapacityExhausted`, `JobFinished`,
    /// `PoolPriceChanged`); per-job events live on each job's own
    /// `result.timeline`.
    pub timeline: Timeline,
    /// Events popped from the shared queue — the numerator of the
    /// events/sec throughput figure.
    pub events_processed: u64,
    /// First arrival to last finish.
    pub makespan: SimDuration,
    /// Peak simultaneously-running instances, cluster-wide.
    pub peak_in_flight: u32,
    /// Peak simultaneously-running instances per pool (the capacity
    /// invariant: `peak_in_flight_per_pool[i] <= capacity[i]`, pinned by
    /// `tests/cluster_invariants.rs`).
    pub peak_in_flight_per_pool: Vec<u32>,
}

impl ClusterResult {
    pub fn completed_jobs(&self) -> usize {
        self.jobs.iter().filter(|j| j.result.completed).count()
    }

    /// How many admissions went through the wait queue.
    pub fn queued_admissions(&self) -> usize {
        self.timeline.count(EventKind::JobQueued)
    }

    pub fn total_cost(&self) -> f64 {
        self.jobs.iter().map(|j| j.result.total_cost()).sum()
    }

    /// Jobs that missed their deadline SLA (0 when the scenario has no
    /// `[job] deadline_mins`).
    pub fn deadline_misses(&self) -> usize {
        self.jobs
            .iter()
            .filter(|j| j.result.deadline_missed == Some(true))
            .count()
    }

    /// Fraction of deadline-carrying jobs that met their SLA, or `None`
    /// when no job carries a deadline verdict.
    pub fn sla_attainment(&self) -> Option<f64> {
        let verdicts =
            self.jobs.iter().filter_map(|j| j.result.deadline_missed);
        let (mut met, mut total) = (0usize, 0usize);
        for missed in verdicts {
            total += 1;
            if !missed {
                met += 1;
            }
        }
        (total > 0).then(|| met as f64 / total as f64)
    }

    /// One-line summary.
    pub fn summary(&self) -> String {
        format!(
            "{}: {}/{} jobs completed in {} | {} events | peak {} in flight \
             | {} queued admission(s) | total {}",
            self.scenario,
            self.completed_jobs(),
            self.jobs.len(),
            self.makespan,
            self.events_processed,
            self.peak_in_flight,
            self.queued_admissions(),
            crate::util::fmt::dollars(self.total_cost()),
        )
    }
}

/// Canonical digest of everything a cluster run produced: the cluster
/// counters and admission timeline plus every job's full [`run_digest`].
/// Two cluster runs are byte-identical iff their digests match — the
/// thread-invariance and engine-equivalence suites compare these.
pub fn cluster_digest(r: &ClusterResult) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = write!(
        out,
        "{}|jobs={}|events={}|makespan={}|peak={}",
        r.scenario,
        r.jobs.len(),
        r.events_processed,
        r.makespan.as_millis(),
        r.peak_in_flight,
    );
    for p in &r.peak_in_flight_per_pool {
        let _ = write!(out, "/{p}");
    }
    // Chaos and market kinds are gated on being observed, exactly like
    // run_digest: a cluster digest without chaos, bids or deadlines stays
    // byte-identical to digests minted before those kinds existed.
    for k in EventKind::ALL {
        if k.is_digest_gated() && r.timeline.count(k) == 0 {
            continue;
        }
        let _ = write!(out, "|#{}={}", k.as_str(), r.timeline.count(k));
    }
    for e in r.timeline.events() {
        let _ = write!(
            out,
            "|{}@{}:{}",
            e.kind.as_str(),
            e.at.as_millis(),
            e.detail
        );
    }
    for j in &r.jobs {
        let _ = write!(
            out,
            "||job:{}|prio={}|sub={}|adm={}|fin={}|{}",
            j.name,
            j.priority,
            j.submitted_at.as_millis(),
            j.admitted_at
                .map(|t| t.as_millis() as i128)
                .unwrap_or(-1),
            j.finished_at.as_millis(),
            run_digest(&j.result),
        );
    }
    out
}

/// The multiplexed engine: one clock, one subject-tagged queue, one live
/// fleet; N private job worlds.
pub struct ClusterEngine<'a> {
    cfg: &'a ScenarioConfig,
    clock: Clock,
    queue: EventQueue<ClusterEvent>,
    price_tokens: Vec<u64>,
    /// Tokens of pending chaos storms — cluster-scoped like the market.
    chaos_tokens: Vec<u64>,
    /// The run's fault schedule (storm instants + IMDS outage windows),
    /// cluster-global and drawn from the scenario seed exactly like the
    /// single-run engine's; empty with `[chaos]` absent.
    plan: FaultPlan,
    fleet: Fleet,
    placement: Box<dyn PlacementPolicy>,
    /// The `[autoscale]` layer over `placement`: bids on spot picks and
    /// overrides them with the on-demand fallback under SLA pressure.
    autoscaler: Option<Autoscaler>,
    jobs: Vec<JobState>,
    /// FIFO wait queue per priority (lower number admits first).
    waiting: BTreeMap<u32, VecDeque<usize>>,
    /// Slots promised to admitted-but-not-yet-launched jobs, per pool —
    /// a slot is held from the placement decision through provisioning.
    reserved: Vec<u32>,
    timeline: Timeline,
    spoton: bool,
    overhead_factor: f64,
    events_processed: u64,
    running_total: u32,
    peak_in_flight: u32,
    pool_peaks: Vec<u32>,
    finished_jobs: usize,
}

impl<'a> ClusterEngine<'a> {
    /// Build the cluster for one scenario. `factories` supplies one
    /// workload factory per configured job (in `[cluster]` job order);
    /// when the scenario has no `[cluster]` section a single job named
    /// after the scenario is assumed.
    pub fn new(
        cfg: &'a ScenarioConfig,
        factories: Vec<JobFactory>,
    ) -> Result<Self> {
        let ccfg = cfg.cluster.clone().unwrap_or_else(|| ClusterCfg {
            jobs: vec![cfg.name.clone()],
            ..ClusterCfg::default()
        });
        ccfg.validate()?;
        if factories.len() != ccfg.jobs.len() {
            bail!(
                "cluster has {} job(s) but {} factories were supplied",
                ccfg.jobs.len(),
                factories.len()
            );
        }
        let fleet = Fleet::from_scenario(cfg)?;
        let placement = build_policy(&cfg.fleet.placement)?;
        let autoscaler = cfg
            .autoscale
            .as_ref()
            .map(|a| Autoscaler::new(a, &fleet))
            .transpose()?;
        let n_pools = fleet.num_pools();
        let spoton = cfg.coordinator_attached;

        let arrivals = arrival_times(&ccfg, cfg.seed);
        let mut jobs = Vec::with_capacity(ccfg.jobs.len());
        for ((i, factory), at) in
            factories.into_iter().enumerate().zip(&arrivals)
        {
            jobs.push(build_job(
                cfg,
                &ccfg.jobs[i],
                ccfg.priority(i),
                *at,
                factory,
                n_pools,
                i as u64,
            )?);
        }
        let plan = match &cfg.chaos {
            Some(chaos) => FaultPlan::draw(chaos, cfg.seed),
            None => FaultPlan::none(),
        };
        Ok(Self {
            cfg,
            clock: Clock::new(),
            queue: EventQueue::new(),
            price_tokens: Vec::new(),
            chaos_tokens: Vec::new(),
            plan,
            fleet,
            placement,
            autoscaler,
            jobs,
            waiting: BTreeMap::new(),
            reserved: vec![0; n_pools],
            timeline: Timeline::with_level(cfg.metrics),
            spoton,
            overhead_factor: if spoton {
                1.0 + cfg.cloud.coordinator_overhead
            } else {
                1.0
            },
            events_processed: 0,
            running_total: 0,
            peak_in_flight: 0,
            pool_peaks: vec![0; n_pools],
            finished_jobs: 0,
        })
    }

    /// Run every job to completion or abort.
    ///
    /// Single-job clusters replay the per-run engine byte for byte. For
    /// multi-job runs two things deliberately differ from "N independent
    /// engines": pools have finite capacity (jobs queue), and
    /// `PoolPriceChanged` is recorded once on the *cluster* timeline
    /// instead of once per job.
    pub fn run(mut self) -> Result<ClusterResult> {
        for j in &mut self.jobs {
            j.writer.resume_after(CheckpointStore::max_id(&mut j.store)?);
        }
        let arrivals: Vec<SimTime> =
            self.jobs.iter().map(|j| j.submitted_at).collect();
        for (job, at) in arrivals.into_iter().enumerate() {
            self.queue.schedule(at, ClusterEvent::JobArrived { job });
        }
        self.fleet
            .splice_market_shocks(&self.plan.market_shocks, self.plan.market_factor);
        self.schedule_price_traces();
        self.schedule_storms();
        while let Some(sch) = self.queue.pop() {
            self.events_processed += 1;
            self.price_tokens.retain(|&t| t != sch.seq);
            self.chaos_tokens.retain(|&t| t != sch.seq);
            self.clock.advance_to(sch.at);
            self.dispatch(sch.event)?;
            if self.finished_jobs == self.jobs.len() {
                break;
            }
        }
        self.finalize()
    }

    fn schedule_price_traces(&mut self) {
        for i in 0..self.fleet.num_pools() {
            let pool = PoolId(i);
            if let Some(first) = self.fleet.price_points(pool).first() {
                let at = SimTime::ZERO + first.offset;
                let token = self
                    .queue
                    .schedule(at, ClusterEvent::PoolPriceChanged { pool, idx: 0 });
                self.price_tokens.push(token);
            }
        }
    }

    /// Arm the plan's storm instants. Storms belong to the cluster, not
    /// to any job: an instance death must not cancel a future storm.
    fn schedule_storms(&mut self) {
        for idx in 0..self.plan.storms.len() {
            let at = self.plan.storms[idx];
            let token =
                self.queue.schedule(at, ClusterEvent::ChaosStorm { idx });
            self.chaos_tokens.push(token);
        }
    }

    // ---------------------------------------------------- event plumbing

    fn sched_job(&mut self, job: usize, at: SimTime, ev: SimEvent) {
        self.queue
            .schedule_for(job, at, ClusterEvent::Job { job, ev });
    }

    fn sched_job_in(&mut self, job: usize, delay: SimDuration, ev: SimEvent) {
        let now = self.clock.now();
        self.queue
            .schedule_for_in(job, now, delay, ClusterEvent::Job { job, ev });
    }

    fn dispatch(&mut self, event: ClusterEvent) -> Result<()> {
        match event {
            ClusterEvent::JobArrived { job } => self.on_job_arrived(job),
            ClusterEvent::Job { job, ev } => self.dispatch_job(job, ev),
            ClusterEvent::PoolPriceChanged { pool, idx } => {
                self.on_price_changed(pool, idx)
            }
            ClusterEvent::ChaosStorm { idx } => self.on_chaos_storm(idx),
        }
    }

    fn dispatch_job(&mut self, job: usize, ev: SimEvent) -> Result<()> {
        match ev {
            SimEvent::ReplacementRequested => self.request_admission(job),
            SimEvent::PlacementDecided { pool } => {
                self.on_placement_decided(job, pool)
            }
            SimEvent::InstanceProvisioned => self.on_instance_provisioned(job),
            SimEvent::RestoreDone { report } => self.on_restore_done(job, report),
            SimEvent::BoundaryReached => self.on_boundary(job),
            SimEvent::StepDone => self.on_step_done(job),
            SimEvent::CkptDone { periodic, outcome } => {
                self.on_ckpt_done(job, periodic, outcome)
            }
            SimEvent::NoticePosted => self.on_notice_posted(job),
            SimEvent::PollTick => self.on_poll_tick(job),
            SimEvent::NoticeDeadline => self.on_instance_reclaimed(job),
            SimEvent::TerminationCkptDone { outcome, notice } => {
                self.on_termination_ckpt_done(job, outcome, notice)
            }
            SimEvent::InstanceEvicted => self.on_instance_reclaimed(job),
            SimEvent::CkptRetry { periodic, attempt } => {
                self.attempt_ckpt(job, periodic, attempt)
            }
            SimEvent::PoolPriceChanged { .. } => {
                unreachable!("price events are cluster-level, never job-tagged")
            }
            SimEvent::ChaosStorm { .. } => {
                unreachable!("storm events are cluster-level, never job-tagged")
            }
        }
    }

    // --------------------------------------------------------- admission

    fn on_job_arrived(&mut self, job: usize) -> Result<()> {
        let now = self.clock.now();
        self.timeline.record_with(now, EventKind::JobSubmitted, || {
            self.jobs[job].name.clone()
        });
        let at = self.jobs[job].submitted_at;
        self.sched_job(job, at, SimEvent::ReplacementRequested);
        Ok(())
    }

    /// One placement decision for `job`: the inner placement policy's
    /// pick, filtered through the autoscaler when one is configured.
    /// Returns the effective pool, the bid the launch should carry, and
    /// — when the autoscaler overrode a spot pick — the shift reason the
    /// caller records iff the placement actually goes through.
    fn place_job(
        &mut self,
        job: usize,
    ) -> (PoolId, Option<f64>, Option<ShiftReason>) {
        let views = self.fleet.views();
        let inner = self.placement.place(self.jobs[job].active, &views);
        let Some(auto) = &self.autoscaler else {
            return (inner, self.fleet.pool_bid(inner), None);
        };
        let now = self.clock.now();
        let ttd = self.cfg.job_deadline.map(|d| {
            let due = self.jobs[job].submitted_at + d;
            if due > now { due.since(now) } else { SimDuration::ZERO }
        });
        let depth =
            self.waiting.values().map(|q| q.len()).sum::<usize>() as u32;
        match auto.decide(&self.fleet, inner, ttd, depth) {
            ScaleDecision::Spot { pool, bid } => {
                (pool, bid.or_else(|| self.fleet.pool_bid(pool)), None)
            }
            ScaleDecision::OnDemand { reason } => (
                auto.on_demand,
                None,
                (reason != ShiftReason::Placement).then_some(reason),
            ),
        }
    }

    /// Record one autoscaler override on the cluster timeline.
    fn record_shift(&mut self, job: usize, pool: PoolId, reason: ShiftReason) {
        let now = self.clock.now();
        self.timeline.record_with(now, EventKind::AutoscaleShift, || {
            format!(
                "{} -> {}: {reason}",
                self.jobs[job].name,
                self.fleet.pool_name(pool)
            )
        });
    }

    /// A job needs an instance: place, then either reserve a slot and
    /// open the provisioning chain, or park in the wait queue.
    fn request_admission(&mut self, job: usize) -> Result<()> {
        let now = self.clock.now();
        let (pool, bid, shift) = self.place_job(job);
        if self.slot_free(pool) {
            if let Some(reason) = shift {
                self.record_shift(job, pool, reason);
            }
            self.jobs[job].pending_bid = bid;
            return self.admit(job, pool);
        }
        let prio = self.jobs[job].priority;
        self.timeline.record_with(now, EventKind::CapacityExhausted, || {
            format!(
                "{}: {} at capacity {}",
                self.jobs[job].name,
                self.fleet.pool_name(pool),
                self.fleet.pool_capacity(pool)
            )
        });
        self.timeline.record_with(now, EventKind::JobQueued, || {
            format!("{} (priority {prio})", self.jobs[job].name)
        });
        self.waiting.entry(prio).or_default().push_back(job);
        Ok(())
    }

    fn slot_free(&self, pool: PoolId) -> bool {
        self.fleet.pool_running(pool) + self.reserved[pool.0]
            < self.fleet.pool_capacity(pool)
    }

    /// Reserve the slot and run the engine's placement-decision step.
    fn admit(&mut self, job: usize, pool: PoolId) -> Result<()> {
        let now = self.clock.now();
        self.reserved[pool.0] += 1;
        if self.jobs[job].admitted_at.is_none() {
            self.jobs[job].admitted_at = Some(now);
        }
        if self.fleet.is_multi_pool() {
            let name = self.placement.name();
            self.jobs[job].timeline.record_with(
                now,
                EventKind::ReplacementRequested,
                || format!("placement via {name}"),
            );
        }
        self.sched_job(job, now, SimEvent::PlacementDecided { pool });
        Ok(())
    }

    /// A slot was freed: admit waiters, head first, strictly FIFO within
    /// each priority. The head waiter re-places against the *current*
    /// views; if its pool is full the whole queue waits behind it.
    fn try_admit_waiting(&mut self) -> Result<()> {
        loop {
            let Some(job) = self.peek_waiting() else { return Ok(()) };
            let (pool, bid, shift) = self.place_job(job);
            if !self.slot_free(pool) {
                return Ok(());
            }
            // spoton-lint: allow(D3, reason = "pop follows a successful peek on the same queue")
            let popped = self.pop_waiting().expect("peeked non-empty");
            debug_assert_eq!(popped, job);
            let now = self.clock.now();
            if let Some(reason) = shift {
                self.record_shift(job, pool, reason);
            }
            self.timeline.record_with(now, EventKind::JobAdmitted, || {
                format!(
                    "{} -> {}",
                    self.jobs[job].name,
                    self.fleet.pool_name(pool)
                )
            });
            self.jobs[job].pending_bid = bid;
            self.admit(job, pool)?;
        }
    }

    fn peek_waiting(&self) -> Option<usize> {
        self.waiting
            .values()
            .find(|q| !q.is_empty())
            // spoton-lint: allow(D3, reason = "empty queues are pruned; fronts exist")
            .map(|q| *q.front().expect("non-empty"))
    }

    fn pop_waiting(&mut self) -> Option<usize> {
        self.waiting.values_mut().find_map(|q| q.pop_front())
    }

    // ----------------------------------------- per-job engine handlers
    //
    // Each mirrors its `super::engine` namesake exactly, with the job's
    // private world substituted for the engine's run-wide state and
    // `cancel_subject` for token-list cancellation.

    fn on_placement_decided(&mut self, job: usize, pool: PoolId) -> Result<()> {
        let now = self.clock.now();
        if pool.0 >= self.fleet.num_pools() {
            bail!(
                "placement picked {pool} but the fleet has {} pool(s)",
                self.fleet.num_pools()
            );
        }
        self.jobs[job].active = pool;
        if self.fleet.is_multi_pool() {
            let views = self.fleet.views();
            let view = &views[pool.0];
            self.jobs[job].timeline.record_with(
                now,
                EventKind::PlacementDecided,
                || {
                    format!(
                        "{} ({} {} @ ${:.4}/h)",
                        view.name,
                        view.vm_size,
                        if view.spot { "spot" } else { "on-demand" },
                        view.price_per_hour
                    )
                },
            );
        }
        // "first launch free" is a per-job rule here (the engine's
        // fleet-wide total_launched test degenerates to this for one job)
        let ready = if self.jobs[job].launches == 0 {
            now
        } else {
            now + self.fleet.pool_provisioning_delay(pool)
        };
        self.sched_job(job, ready, SimEvent::InstanceProvisioned);
        Ok(())
    }

    fn on_instance_provisioned(&mut self, job: usize) -> Result<()> {
        let now = self.clock.now();
        let pool = self.jobs[job].active;
        let iid = self.fleet.launch_in(pool, now).id;
        self.reserved[pool.0] -= 1;
        self.running_total += 1;
        self.peak_in_flight = self.peak_in_flight.max(self.running_total);
        let running = self.fleet.pool_running(pool);
        self.pool_peaks[pool.0] = self.pool_peaks[pool.0].max(running);

        let inst_id = iid.to_string();
        let multi = self.fleet.is_multi_pool();
        {
            let fleet = &self.fleet;
            let j = &mut self.jobs[job];
            j.controller.observe_launch(pool, now);
            j.launches += 1;
            j.pool_counts[pool.0].0 += 1;
            j.timeline.record_with(now, EventKind::InstanceLaunch, || {
                if multi {
                    format!("{inst_id} in {}", fleet.pool_name(pool))
                } else {
                    inst_id.clone()
                }
            });
            let mut monitor = ScheduledEventsMonitor::new(&inst_id);
            monitor.reset();
            j.monitor = Some(monitor);
        }

        let spoton = self.spoton;
        let notice = self.cfg.cloud.notice;
        let poll_interval = self.cfg.cloud.poll_interval;
        let schedule = self.fleet.next_eviction_offset_in(pool).map(|offset| {
            let post = now + offset;
            let deadline = post + notice;
            let detect = if !spoton {
                deadline
            } else {
                let since_start = post.since(now).as_millis();
                let poll = poll_interval.as_millis().max(1);
                let ticks = since_start.div_ceil(poll);
                now + SimDuration::from_millis(ticks * poll)
            };
            EvictionSchedule { post, detect, deadline }
        });
        let bid = self.jobs[job].pending_bid.take();
        self.jobs[job].inst = Some(JobInstance {
            id: inst_id,
            iid,
            pool,
            schedule,
            started: now,
            bid,
            outbid_at: None,
        });
        // born outbid: the market may already sit above the bid decided
        // at admission (a price epoch landed during provisioning)
        self.check_outbid_job(job, pool, self.fleet.pool_price(pool), now);

        if spoton {
            // Fallback search: a committed generation that fails
            // verification (chaos corruption) is skipped — recorded as a
            // fallback — and the next-newest verified one restores. With
            // chaos off every committed generation verifies, so this is
            // exactly the classic most-recent-valid lookup.
            let search = {
                let j = &mut self.jobs[job];
                let search = RestartManager::find_and_restore_with_fallback(
                    &mut j.store,
                    &j.policy,
                    j.workload.as_mut(),
                )
                .context("restart")?;
                for (id, problem) in &search.skipped {
                    j.timeline.record_with(
                        now,
                        EventKind::RestoreFallback,
                        || format!("ckpt {id} unusable ({problem})"),
                    );
                }
                search
            };
            match search.report {
                Some(report) => {
                    let cost = report.cost;
                    self.sched_job_in(job, cost, SimEvent::RestoreDone {
                        report,
                    });
                    return Ok(());
                }
                None => {
                    let j = &mut self.jobs[job];
                    if !search.skipped.is_empty() {
                        j.timeline.record(
                            now,
                            EventKind::UnrecoveredRestore,
                            "every committed generation failed verification",
                        );
                    }
                    if j.evictions > 0 {
                        j.workload = (j.factory)()?;
                        j.lost_steps += j.max_steps_seen;
                    }
                }
            }
        } else if self.jobs[job].evictions > 0 {
            let j = &mut self.jobs[job];
            j.workload = (j.factory)()?;
            j.lost_steps += j.max_steps_seen;
        }

        self.jobs[job].last_ckpt_at = now;
        self.sched_job(job, now, SimEvent::BoundaryReached);
        Ok(())
    }

    fn on_restore_done(&mut self, job: usize, report: RestoreReport) -> Result<()> {
        let now = self.clock.now();
        let j = &mut self.jobs[job];
        j.restores += 1;
        j.controller.observe_restore(now);
        j.lost_steps +=
            j.max_steps_seen.saturating_sub(report.resumed_total_steps);
        j.timeline.record_with(now, EventKind::RestoreFromCheckpoint, || {
            format!(
                "ckpt {} ({}) -> step {}",
                report.manifest.id,
                report.manifest.kind.as_str(),
                report.resumed_total_steps
            )
        });
        j.last_ckpt_at = now;
        self.sched_job(job, now, SimEvent::BoundaryReached);
        Ok(())
    }

    fn on_boundary(&mut self, job: usize) -> Result<()> {
        let now = self.clock.now();
        if now.since(SimTime::ZERO) >= self.cfg.deadline {
            let reason = format!("deadline {} exceeded", self.cfg.deadline);
            self.jobs[job]
                .timeline
                .record(now, EventKind::Aborted, reason.clone());
            self.jobs[job].aborted_reason = Some(reason);
            return self.finish_job(job, now);
        }

        if self.spoton && self.periodic_due(job, now) {
            return self.attempt_ckpt(job, true, 0);
        }

        self.decide_step(job)
    }

    /// One checkpoint write attempt for `job` — the per-job mirror of the
    /// engine's `attempt_ckpt`: an injected storage fault burns the
    /// virtual time the transfer consumed and, while the retry policy has
    /// attempts left, schedules a [`SimEvent::CkptRetry`] after the
    /// backoff delay instead of failing the run.
    fn attempt_ckpt(
        &mut self,
        job: usize,
        periodic: bool,
        attempt: u32,
    ) -> Result<()> {
        let now = self.clock.now();
        let kind =
            if periodic { CkptKind::Periodic } else { CkptKind::AppNative };
        let snapped = {
            let j = &mut self.jobs[job];
            if periodic {
                j.workload.snapshot_into(&mut j.snap_buf)?;
                true
            } else {
                match j.workload.app_snapshot()? {
                    Some(snap) => {
                        j.snap_buf = snap;
                        true
                    }
                    // nothing to capture at this milestone — back to the
                    // boundary (also covers a retry outliving its
                    // milestone)
                    None => false,
                }
            }
        };
        if !snapped {
            self.sched_job(job, now, SimEvent::BoundaryReached);
            return Ok(());
        }
        let res = {
            let j = &mut self.jobs[job];
            j.writer.write(
                &mut j.store,
                now,
                kind,
                j.workload.as_ref(),
                &j.snap_buf,
            )
        };
        match res {
            Ok(outcome) => {
                self.drain_faults(job, now);
                let cost = outcome.cost();
                self.sched_job_in(job, cost, SimEvent::CkptDone {
                    periodic,
                    outcome,
                });
                Ok(())
            }
            Err(e) => match e.downcast_ref::<InjectedFault>() {
                Some(fault) => {
                    let burned = fault.burned;
                    self.drain_faults(job, now);
                    self.on_ckpt_fault(job, periodic, attempt, burned)
                }
                None => Err(e),
            },
        }
    }

    /// A job's checkpoint write died on an injected storage fault: retry
    /// under its backoff policy, or surrender the generation and move on.
    fn on_ckpt_fault(
        &mut self,
        job: usize,
        periodic: bool,
        attempt: u32,
        burned: SimDuration,
    ) -> Result<()> {
        let now = self.clock.now();
        let label = if periodic { "periodic" } else { "application" };
        let j = &mut self.jobs[job];
        let can_retry =
            j.backoff.as_ref().map_or(false, |b| b.retries_left(attempt));
        if can_retry {
            let delay = j
                .backoff
                .as_mut()
                // spoton-lint: allow(D3, reason = "retry policies are constructed with a backoff")
                .expect("retries imply a backoff policy")
                .delay(attempt);
            j.timeline.record_with(now, EventKind::CkptRetried, || {
                format!(
                    "{label} ckpt attempt {} failed; retry in {delay}",
                    attempt + 1
                )
            });
            self.sched_job_in(job, burned + delay, SimEvent::CkptRetry {
                periodic,
                attempt: attempt + 1,
            });
        } else {
            j.timeline.record_with(now, EventKind::CheckpointFailed, || {
                format!(
                    "{label} ckpt failed after {} attempt(s); \
                     generation lost",
                    attempt + 1
                )
            });
            if periodic {
                // the cadence clock still advances: the next due test
                // starts from the failure, not the last success
                j.last_ckpt_at = now;
            }
            self.sched_job_in(job, burned, SimEvent::BoundaryReached);
        }
        Ok(())
    }

    /// Surface one job's injected-fault log onto its timeline.
    fn drain_faults(&mut self, job: usize, now: SimTime) {
        let j = &mut self.jobs[job];
        for f in j.store.take_faults() {
            let kind = match f.kind {
                FaultKind::WriteFail => EventKind::ChaosWriteFault,
                FaultKind::TornWrite => EventKind::ChaosTornWrite,
                FaultKind::Corrupt => EventKind::ChaosCorruption,
                FaultKind::LatencySpike => EventKind::ChaosLatencySpike,
            };
            j.timeline.record(now, kind, f.key);
        }
    }

    fn periodic_due(&mut self, job: usize, now: SimTime) -> bool {
        let pool = self.jobs[job]
            .inst
            .as_ref()
            .map(|i| i.pool)
            .unwrap_or(self.jobs[job].active);
        let price_factor = self.fleet.price_factor(pool);
        let j = &mut self.jobs[job];
        let Some(base) = j.policy.periodic_interval() else {
            return false;
        };
        let ctx = PolicyCtx {
            now,
            last_ckpt: j.last_ckpt_at,
            base_interval: base,
            ckpt_cost: j.ckpt_cost_est,
            pool,
            price_factor,
        };
        now.since(j.last_ckpt_at) >= j.controller.next_interval(&ctx)
    }

    fn decide_step(&mut self, job: usize) -> Result<()> {
        let now = self.clock.now();
        let j = &self.jobs[job];
        let stage = j.workload.progress().stage as usize;
        let step_cost = SimDuration::from_secs_f64(
            self.cfg.workload.stage_secs[stage] as f64
                / j.workload.stage_steps(stage as u32) as f64
                * self.overhead_factor,
        );

        if let Some(es) = j.inst.as_ref().and_then(|inst| inst.schedule) {
            let step_end = now + step_cost;
            if es.detect <= step_end || es.deadline <= step_end {
                let post_visible = es.post.max(now);
                let token = self.queue.schedule_for(
                    job,
                    post_visible,
                    ClusterEvent::Job { job, ev: SimEvent::NoticePosted },
                );
                // remembered so a storm can pull the post forward
                self.jobs[job].notice_token = Some(token);
                return Ok(());
            }
        }

        self.sched_job_in(job, step_cost, SimEvent::StepDone);
        Ok(())
    }

    fn on_step_done(&mut self, job: usize) -> Result<()> {
        let now = self.clock.now();
        let j = &mut self.jobs[job];
        let outcome = j.workload.step()?;
        j.max_steps_seen = j.max_steps_seen.max(j.workload.progress().total_steps);

        let mut milestone = false;
        match outcome {
            StepOutcome::Advanced => {}
            StepOutcome::Milestone => milestone = true,
            StepOutcome::StageComplete(s) => {
                milestone = true;
                j.completion_at[s as usize] = Some(now);
                j.timeline.record_with(now, EventKind::StageComplete, || {
                    j.workload.stage_label(s)
                });
            }
            StepOutcome::Done => {
                let s = (j.workload.num_stages() - 1) as usize;
                j.completion_at[s] = Some(now);
                j.timeline.record_with(now, EventKind::StageComplete, || {
                    j.workload.stage_label(s as u32)
                });
                j.timeline.record_with(now, EventKind::WorkloadDone, || {
                    format!("{} steps", j.workload.progress().total_steps)
                });
                j.completed = true;
                return self.finish_job(job, now);
            }
        }

        if milestone
            && self.spoton
            && self.jobs[job].policy.persists_app_milestones()
        {
            // attempt_ckpt falls back to the boundary itself when the
            // workload has no milestone snapshot to offer
            return self.attempt_ckpt(job, false, 0);
        }

        self.sched_job(job, now, SimEvent::BoundaryReached);
        Ok(())
    }

    fn on_ckpt_done(
        &mut self,
        job: usize,
        periodic: bool,
        outcome: crate::checkpoint::WriteOutcome,
    ) -> Result<()> {
        let now = self.clock.now();
        let j = &mut self.jobs[job];
        if periodic {
            j.controller.observe_ckpt_cost(outcome.cost());
        }
        if let Some(manifest) = outcome.committed() {
            if periodic {
                j.periodic_ckpts += 1;
                j.timeline.record_with(now, EventKind::CheckpointCommitted, || {
                    format!("periodic ckpt {}", manifest.id)
                });
            } else {
                j.app_ckpts += 1;
                j.timeline.record_with(now, EventKind::CheckpointCommitted, || {
                    format!("application ckpt {}", manifest.id)
                });
            }
        }
        CheckpointStore::gc(&mut j.store, self.cfg.retain as usize)?;
        if periodic {
            j.last_ckpt_at = now;
            self.decide_step(job)
        } else {
            self.sched_job(job, now, SimEvent::BoundaryReached);
            Ok(())
        }
    }

    fn on_notice_posted(&mut self, job: usize) -> Result<()> {
        let now = self.clock.now();
        let j = &mut self.jobs[job];
        j.notice_token = None;
        let (inst_id, es) = {
            let inst = j
                .inst
                .as_ref()
                // spoton-lint: allow(D3, reason = "event-queue invariant: events only target live instances")
                .expect("notice events require a live instance");
            (
                inst.id.clone(),
                // spoton-lint: allow(D3, reason = "eviction events are only scheduled with a schedule set")
                inst.schedule.expect("notice without an eviction schedule"),
            )
        };
        let detail = j.metadata.post_preempt(&inst_id, es.deadline);
        j.timeline.record(now, EventKind::EvictionNotice, detail);
        j.notices += 1;

        if !self.spoton || es.detect >= es.deadline {
            self.sched_job(job, es.deadline.max(now), SimEvent::NoticeDeadline);
        } else {
            self.sched_job(job, es.detect.max(now), SimEvent::PollTick);
        }
        Ok(())
    }

    fn on_poll_tick(&mut self, job: usize) -> Result<()> {
        let now = self.clock.now();
        let deadline = self.jobs[job]
            .inst
            .as_ref()
            .and_then(|inst| inst.schedule)
            // spoton-lint: allow(D3, reason = "eviction events are only scheduled with a schedule set")
            .expect("poll tick without an eviction schedule")
            .deadline;
        if self.plan.imds_down(now) {
            // IMDS outage: this poll sees nothing. The monitor degrades
            // to a slower cadence and keeps polling; if even the
            // degraded tick cannot land before the reclaim instant, the
            // notice goes unobserved and the platform simply kills the
            // instance at the deadline — degraded, accounted, never
            // wedged.
            let end = self.plan.outage_ends(now);
            let degraded =
                self.plan.degraded_poll(self.cfg.cloud.poll_interval);
            let j = &mut self.jobs[job];
            if !j.imds_was_down {
                j.imds_was_down = true;
                j.metadata.set_available(false);
                j.timeline.record_with(now, EventKind::ImdsOutage, || {
                    match end {
                        Some(end) => format!(
                            "scheduled-events endpoint down until {end}"
                        ),
                        None => "scheduled-events endpoint down".into(),
                    }
                });
            }
            j.timeline.record_with(now, EventKind::PollDegraded, || {
                format!("poll backed off to {degraded}")
            });
            let next = now + degraded;
            if next < deadline {
                self.sched_job(job, next, SimEvent::PollTick);
            } else {
                self.sched_job(
                    job,
                    deadline.max(now),
                    SimEvent::NoticeDeadline,
                );
            }
            return Ok(());
        }
        let reaction = {
            let j = &mut self.jobs[job];
            if j.imds_was_down {
                j.imds_was_down = false;
                j.metadata.set_available(true);
            }
            handlers::on_poll_tick(
                // spoton-lint: allow(D3, reason = "live instances always carry a monitor")
                j.monitor.as_mut().expect("live instance has a monitor"),
                &mut j.metadata,
                &j.policy,
                &mut j.writer,
                &mut j.store,
                j.workload.as_ref(),
                now,
                deadline,
            )?
        };
        self.drain_faults(job, now);
        match reaction {
            PollReaction::TerminationCkpt { notice, outcome } => {
                let cost = outcome.cost();
                self.sched_job_in(job, cost, SimEvent::TerminationCkptDone {
                    outcome,
                    notice,
                });
            }
            PollReaction::AckOnly => {
                self.sched_job(job, now, SimEvent::InstanceEvicted);
            }
        }
        Ok(())
    }

    fn on_termination_ckpt_done(
        &mut self,
        job: usize,
        outcome: crate::checkpoint::WriteOutcome,
        notice: Notice,
    ) -> Result<()> {
        let now = self.clock.now();
        let j = &mut self.jobs[job];
        if let Some(manifest) = outcome.committed() {
            j.termination_ok += 1;
            j.timeline.record_with(now, EventKind::CheckpointCommitted, || {
                format!("termination ckpt {}", manifest.id)
            });
        } else {
            j.termination_failed += 1;
            j.timeline.record(
                now,
                EventKind::CheckpointFailed,
                "termination ckpt missed deadline",
            );
        }
        handlers::ack_notice(
            // spoton-lint: allow(D3, reason = "live instances always carry a monitor")
            j.monitor.as_ref().expect("live instance has a monitor"),
            &mut j.metadata,
            &notice,
        );
        self.sched_job(job, now, SimEvent::InstanceEvicted);
        Ok(())
    }

    /// The instance dies: bill it, free its slot, admit waiters, then let
    /// the evicted job re-request (it joins the back of the queue if the
    /// fleet is still full — waiters are never starved by churners).
    fn on_instance_reclaimed(&mut self, job: usize) -> Result<()> {
        let now = self.clock.now();
        let inst = self.jobs[job]
            .inst
            .take()
            // spoton-lint: allow(D3, reason = "event-queue invariant: events only target live instances")
            .expect("reclaim events require a live instance");
        let pool = inst.pool;
        let terminated = match inst.outbid_at {
            // billing stops at the crossing, not the reclaim
            Some(at) => self.fleet.terminate_in_outbid(
                pool,
                inst.iid,
                now,
                at,
                &mut self.jobs[job].billing,
            ),
            None => self.fleet.terminate_in(
                pool,
                inst.iid,
                now,
                &mut self.jobs[job].billing,
            ),
        };
        if terminated {
            self.running_total -= 1;
            self.fleet.note_eviction(pool);
            self.jobs[job].controller.observe_eviction(pool, now);
            self.jobs[job].pool_counts[pool.0].1 += 1;
        }
        let j = &mut self.jobs[job];
        j.metadata.clear_resource(&inst.id);
        j.evictions += 1;
        j.timeline.record(now, EventKind::InstanceEvicted, inst.id);
        j.notice_token = None;
        self.queue.cancel_subject(job);
        self.try_admit_waiting()?;
        self.sched_job(job, now, SimEvent::ReplacementRequested);
        Ok(())
    }

    fn on_price_changed(&mut self, pool: PoolId, idx: usize) -> Result<()> {
        let now = self.clock.now();
        let (point, next) = {
            let points = self.fleet.price_points(pool);
            (points[idx], points.get(idx + 1).copied())
        };
        let (old, new) = self.fleet.apply_price_factor(pool, point.factor, now);
        // the one documented O(jobs) event: market moves are trace-rare
        // and every live controller must see them
        for j in self.jobs.iter_mut().filter(|j| !j.finished) {
            j.controller.observe_price(pool, point.factor);
        }
        self.timeline.record_with(now, EventKind::PoolPriceChanged, || {
            format!(
                "{}: ${old:.4}/h -> ${new:.4}/h (x{})",
                self.fleet.pool_name(pool),
                point.factor
            )
        });
        if let Some(next) = next {
            let token = self.queue.schedule(
                SimTime::ZERO + next.offset,
                ClusterEvent::PoolPriceChanged { pool, idx: idx + 1 },
            );
            self.price_tokens.push(token);
        }
        // outbid fan-out in job index order (deterministic, and bounded
        // like the controller loop above: trace length × jobs)
        let price = self.fleet.pool_price(pool);
        for job in 0..self.jobs.len() {
            if !self.jobs[job].finished {
                self.check_outbid_job(job, pool, price, now);
            }
        }
        Ok(())
    }

    /// Did this price epoch outbid `job`'s live instance? Mirrors the
    /// per-run engine's `check_outbid`: mark the billing cut at the
    /// crossing, then rewrite the eviction schedule so the notice posts
    /// *now* (the platform still grants the configured notice window),
    /// exactly like a storm — unless an eviction is already in flight,
    /// in which case only the billing cut applies.
    fn check_outbid_job(
        &mut self,
        job: usize,
        pool: PoolId,
        price: f64,
        now: SimTime,
    ) {
        let Some(inst) = self.jobs[job].inst.as_ref() else { return };
        if inst.pool != pool || inst.outbid_at.is_some() {
            return;
        }
        let Some(bid) = inst.bid else { return };
        if price <= bid {
            return;
        }
        let started = inst.started;
        let already_posted =
            inst.schedule.map_or(false, |es| es.post <= now);
        // spoton-lint: allow(D3, reason = "checked Some above; no reentrancy between the checks")
        let inst = self.jobs[job].inst.as_mut().expect("checked live above");
        inst.outbid_at = Some(now);
        self.jobs[job].timeline.record_with(now, EventKind::PoolOutbid, || {
            format!(
                "{}: price ${price:.4}/h crossed bid ${bid:.4}/h",
                self.fleet.pool_name(pool)
            )
        });
        if already_posted {
            return;
        }
        let post = now;
        let deadline = post + self.cfg.cloud.notice;
        let detect = if !self.spoton {
            deadline
        } else {
            // first poll tick at/after the post, ticks measured from the
            // instance's launch — same rule as the planned schedule
            let since_start = post.since(started).as_millis();
            let poll = self.cfg.cloud.poll_interval.as_millis().max(1);
            let ticks = since_start.div_ceil(poll);
            started + SimDuration::from_millis(ticks * poll)
        };
        if let Some(inst) = self.jobs[job].inst.as_mut() {
            inst.schedule = Some(EvictionSchedule { post, detect, deadline });
        }
        // a boundary already committed to the (later) planned post: pull
        // the pending NoticePosted forward to now
        if let Some(token) = self.jobs[job].notice_token.take() {
            self.queue.cancel(token);
            let new_token = self.queue.schedule_for(
                job,
                now,
                ClusterEvent::Job { job, ev: SimEvent::NoticePosted },
            );
            self.jobs[job].notice_token = Some(new_token);
        }
    }

    /// A planned eviction storm lands cluster-wide: every unfinished
    /// job's live instance gets its eviction schedule rewritten so the
    /// Preempt posts *now* (the platform still grants the configured
    /// notice before reclaiming) — the correlated multi-pool capacity
    /// event the per-run engine's storm models for one instance. Jobs
    /// without a live instance — queued, provisioning, or between
    /// instances — ride the storm out: storms hit instances, not work.
    fn on_chaos_storm(&mut self, idx: usize) -> Result<()> {
        let now = self.clock.now();
        for job in 0..self.jobs.len() {
            if self.jobs[job].finished {
                continue;
            }
            self.storm_job(job, idx, now);
        }
        Ok(())
    }

    /// Apply storm `idx` to one job — the per-job mirror of the engine's
    /// `on_chaos_storm`, recorded on the job's own timeline.
    fn storm_job(&mut self, job: usize, idx: usize, now: SimTime) {
        let started = match &self.jobs[job].inst {
            Some(inst) => inst.started,
            None => {
                self.jobs[job].timeline.record_with(
                    now,
                    EventKind::ChaosStorm,
                    || format!("storm {idx}: no live instance"),
                );
                return;
            }
        };
        let already_posted = self.jobs[job]
            .inst
            .as_ref()
            .and_then(|inst| inst.schedule)
            .map_or(false, |es| es.post <= now);
        if already_posted {
            self.jobs[job].timeline.record_with(
                now,
                EventKind::ChaosStorm,
                || format!("storm {idx}: eviction already in flight"),
            );
            return;
        }
        let post = now;
        let deadline = post + self.cfg.cloud.notice;
        let detect = if !self.spoton {
            deadline
        } else {
            // first poll tick at/after the post, ticks measured from the
            // instance's launch — same rule as the planned schedule
            let since_start = post.since(started).as_millis();
            let poll = self.cfg.cloud.poll_interval.as_millis().max(1);
            let ticks = since_start.div_ceil(poll);
            started + SimDuration::from_millis(ticks * poll)
        };
        if let Some(inst) = self.jobs[job].inst.as_mut() {
            inst.schedule = Some(EvictionSchedule { post, detect, deadline });
        }
        // if the job's boundary already committed to the (later) planned
        // post, pull that pending NoticePosted forward to now
        if let Some(token) = self.jobs[job].notice_token.take() {
            self.queue.cancel(token);
            let new_token = self.queue.schedule_for(
                job,
                now,
                ClusterEvent::Job { job, ev: SimEvent::NoticePosted },
            );
            self.jobs[job].notice_token = Some(new_token);
        }
        self.jobs[job].timeline.record_with(now, EventKind::ChaosStorm, || {
            format!("storm {idx}: eviction rescheduled to now")
        });
    }

    // ------------------------------------------------------- run ending

    /// A job ends (workload done or deadline abort): terminate its
    /// instance, drop its pending events, free the slot for waiters.
    fn finish_job(&mut self, job: usize, now: SimTime) -> Result<()> {
        if let Some(inst) = self.jobs[job].inst.take() {
            let terminated = match inst.outbid_at {
                Some(at) => self.fleet.terminate_in_outbid(
                    inst.pool,
                    inst.iid,
                    now,
                    at,
                    &mut self.jobs[job].billing,
                ),
                None => self.fleet.terminate_in(
                    inst.pool,
                    inst.iid,
                    now,
                    &mut self.jobs[job].billing,
                ),
            };
            if terminated {
                self.running_total -= 1;
            }
        }
        if let Some(d) = self.cfg.job_deadline {
            let total = now.since(self.jobs[job].submitted_at);
            let completed = self.jobs[job].completed;
            if !completed || total > d {
                self.jobs[job].timeline.record_with(
                    now,
                    EventKind::DeadlineMissed,
                    || {
                        if completed {
                            format!("finished at {total}, deadline {d}")
                        } else {
                            format!("did not finish; deadline {d}")
                        }
                    },
                );
            }
        }
        self.jobs[job].finished = true;
        self.jobs[job].finished_at = Some(now);
        self.jobs[job].notice_token = None;
        self.queue.cancel_subject(job);
        self.finished_jobs += 1;
        self.timeline.record_with(now, EventKind::JobFinished, || {
            let j = &self.jobs[job];
            format!(
                "{} ({})",
                j.name,
                if j.completed { "completed" } else { "aborted" }
            )
        });
        if self.finished_jobs == self.jobs.len() {
            for token in self.price_tokens.drain(..) {
                self.queue.cancel(token);
            }
            for token in self.chaos_tokens.drain(..) {
                self.queue.cancel(token);
            }
        } else {
            self.try_admit_waiting()?;
        }
        Ok(())
    }

    fn finalize(self) -> Result<ClusterResult> {
        let cfg = self.cfg;
        let end = self.clock.now();
        let views = self.fleet.views();
        let multi = self.fleet.is_multi_pool();
        let spoton = self.spoton;

        let mut outcomes = Vec::with_capacity(self.jobs.len());
        for j in self.jobs {
            let finished_at = j.finished_at.unwrap_or(end);
            let total = finished_at.since(j.submitted_at);
            let mut billing = j.billing;
            if spoton && j.policy.protected() {
                billing.book_storage(
                    "nfs-share",
                    cfg.storage.provisioned_gib,
                    total,
                    cfg.storage.price_per_100gib_month,
                );
            }

            let mut stage_times = Vec::new();
            let mut prev = j.submitted_at;
            for (i, at) in j.completion_at.iter().enumerate() {
                if let Some(t) = at {
                    stage_times
                        .push((j.workload.stage_label(i as u32), t.since(prev)));
                    prev = *t;
                }
            }
            if let Some(reason) = &j.aborted_reason {
                log::warn!("{}: {reason}", j.name);
            }
            let pool_stats = views
                .iter()
                .enumerate()
                .map(|(i, v)| PoolStats {
                    pool: v.name.clone(),
                    vm_size: v.vm_size.clone(),
                    spot: v.spot,
                    launches: j.pool_counts[i].0,
                    evictions: j.pool_counts[i].1,
                    compute_cost: if multi {
                        billing.pool_compute_total(&v.name)
                    } else {
                        billing.compute_total()
                    },
                })
                .collect();

            let result = RunResult {
                scenario: j.name.clone(),
                completed: j.completed,
                deadline_missed: cfg
                    .job_deadline
                    .map(|d| !j.completed || total > d),
                stage_times,
                total,
                notices: j.notices,
                evictions: j.evictions,
                instances: j.launches,
                periodic_ckpts: j.periodic_ckpts,
                termination_ok: j.termination_ok,
                termination_failed: j.termination_failed,
                app_ckpts: j.app_ckpts,
                restores: j.restores,
                lost_steps: j.lost_steps,
                compute_cost: billing.compute_total(),
                storage_cost: billing.storage_total(),
                invoice: billing.invoice(),
                pool_stats,
                timeline: j.timeline,
                final_fingerprint: j.workload.fingerprint(),
            };
            outcomes.push(JobOutcome {
                name: j.name,
                priority: j.priority,
                submitted_at: j.submitted_at,
                admitted_at: j.admitted_at,
                finished_at,
                result,
            });
        }

        Ok(ClusterResult {
            scenario: cfg.name.clone(),
            jobs: outcomes,
            timeline: self.timeline,
            events_processed: self.events_processed,
            makespan: end.since(SimTime::ZERO),
            peak_in_flight: self.peak_in_flight,
            peak_in_flight_per_pool: self.pool_peaks,
        })
    }
}

/// Arrival instants per job, in job order. Poisson draws come from a
/// dedicated salt of the scenario seed so arrivals never perturb
/// eviction plans or price walks.
fn arrival_times(ccfg: &ClusterCfg, seed: u64) -> Vec<SimTime> {
    let n = ccfg.jobs.len();
    match &ccfg.arrival {
        ArrivalCfg::Batch => vec![SimTime::ZERO; n],
        ArrivalCfg::Uniform { spacing } => (0..n as u64)
            .map(|i| {
                SimTime::ZERO + SimDuration::from_millis(spacing.as_millis() * i)
            })
            .collect(),
        ArrivalCfg::Poisson { mean } => {
            let mut rng = Prng::new(seed ^ ARRIVAL_SEED_SALT);
            let mean_s = mean.as_secs_f64();
            let mut t = SimTime::ZERO;
            (0..n)
                .map(|_| {
                    t = t + SimDuration::from_secs_f64(rng.exp(mean_s));
                    t
                })
                .collect()
        }
    }
}

fn build_job(
    cfg: &ScenarioConfig,
    name: &str,
    priority: u32,
    submitted_at: SimTime,
    mut factory: JobFactory,
    n_pools: usize,
    idx: u64,
) -> Result<JobState> {
    let workload = factory()
        .with_context(|| format!("building workload for job '{name}'"))?;
    let n_stages = workload.num_stages() as usize;
    if cfg.workload.stage_secs.len() != n_stages {
        bail!(
            "scenario has {} stage durations but workload has {} stages",
            cfg.workload.stage_secs.len(),
            n_stages
        );
    }
    let policy = CheckpointPolicy::new(cfg.checkpoint.clone())
        .with_compression(cfg.compress_termination)
        .with_controller(cfg.adaptive.clone());
    if policy.periodic_interval().is_none()
        && *policy.controller() != crate::config::IntervalControllerCfg::Fixed
    {
        bail!(
            "adaptive interval controller '{}' requires the transparent \
             checkpoint method (it tunes the periodic interval)",
            policy.controller().label()
        );
    }
    let controller = build_controller(policy.controller())?;
    let store = BlobStore::new(
        TransferModel {
            bandwidth_mib_s: cfg.storage.bandwidth_mib_s,
            latency: cfg.storage.latency,
        },
        Some(cfg.storage.provisioned_gib),
    );
    let ckpt_cost_est = store
        .transfer_cost((cfg.workload.state_gib * (1u64 << 30) as f64) as u64);
    // Per-job chaos decorrelation: job 0 draws the single-run engine's
    // exact fault and jitter streams (the equivalence pin); later jobs
    // stride off them so no two jobs share a fault sequence.
    let store = match &cfg.chaos {
        Some(chaos) => ChaosStore::new(
            store,
            chaos.storage.clone(),
            super::chaos::job_storage_seed(cfg.seed, chaos.salt, idx),
        ),
        None => ChaosStore::passthrough(store),
    };
    let backoff = cfg
        .retry
        .as_ref()
        .map(|r| {
            Backoff::new(r.clone(), super::chaos::job_backoff_seed(cfg.seed, idx))
        })
        .transpose()?;
    Ok(JobState {
        name: name.to_string(),
        priority,
        factory,
        store,
        workload,
        policy,
        controller,
        ckpt_cost_est,
        billing: BillingMeter::new(),
        timeline: Timeline::with_level(cfg.metrics),
        metadata: MetadataService::new(),
        writer: CheckpointWriter::new(),
        monitor: None,
        inst: None,
        snap_buf: Snapshot { bytes: Vec::new(), charged_bytes: 0 },
        backoff,
        imds_was_down: false,
        notice_token: None,
        pending_bid: None,
        active: PoolId(0),
        pool_counts: vec![(0, 0); n_pools],
        launches: 0,
        submitted_at,
        admitted_at: None,
        finished_at: None,
        last_ckpt_at: SimTime::ZERO,
        completion_at: vec![None; n_stages],
        notices: 0,
        evictions: 0,
        periodic_ckpts: 0,
        termination_ok: 0,
        termination_failed: 0,
        app_ckpts: 0,
        restores: 0,
        lost_steps: 0,
        max_steps_seen: 0,
        completed: false,
        aborted_reason: None,
        finished: false,
    })
}

// ------------------------------------------------------- sweep driver

/// One merged cluster-sweep entry.
#[derive(Debug)]
pub struct SeededClusterRun {
    pub seed: u64,
    pub result: ClusterResult,
}

/// Monte Carlo sweep over one base cluster scenario: each seeded run is
/// one sequential cluster engine; the sweep parallelizes **across runs**
/// and merges by seed position, so the merged vector is byte-identical
/// at any thread count (pinned by `tests/sweep_determinism.rs`).
#[derive(Debug, Clone)]
pub struct ClusterSweep {
    base: Experiment,
    seeds: Vec<u64>,
    threads: usize,
    record: RecordLevel,
}

impl Experiment {
    /// Run this scenario's `[cluster]` with one sleeper workload per job.
    pub fn run_cluster_sleeper(&self) -> Result<ClusterResult> {
        let n = self.cfg.cluster.as_ref().map_or(1, |c| c.jobs.len());
        let factories = (0..n).map(|_| self.sleeper_factory()).collect();
        ClusterEngine::new(&self.cfg, factories)?.run()
    }

    /// Start a cluster sweep over this experiment.
    pub fn cluster_sweep(&self) -> ClusterSweep {
        ClusterSweep::new(self.clone())
    }
}

impl ClusterSweep {
    pub fn new(base: Experiment) -> Self {
        Self {
            base,
            seeds: Vec::new(),
            // spoton-lint: allow(D2, reason = "worker-count default only; merged results are seed-keyed")
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            record: RecordLevel::Counts,
        }
    }

    /// Explicit seed list (merge order == this order).
    pub fn seeds(mut self, seeds: impl IntoIterator<Item = u64>) -> Self {
        self.seeds = seeds.into_iter().collect();
        self
    }

    /// The contiguous seed range `first .. first + n`.
    pub fn seed_range(self, first: u64, n: usize) -> Self {
        let seeds: Vec<u64> =
            (0..n as u64).map(|i| first.wrapping_add(i)).collect();
        self.seeds(seeds)
    }

    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    pub fn record(mut self, level: RecordLevel) -> Self {
        self.record = level;
        self
    }

    /// One run at `seed`, exactly as the sweep executes it.
    pub fn run_one(&self, seed: u64) -> Result<ClusterResult> {
        let mut exp = self.base.clone().seed(seed);
        exp.cfg.metrics = self.record;
        exp.run_cluster_sleeper()
    }

    /// Run every seed and merge by seed position (same worker scheme as
    /// [`super::sweep::Sweep::run`]: atomic work index, local stashes,
    /// position merge — worker identity never leaks into the output).
    pub fn run(&self) -> Result<Vec<SeededClusterRun>> {
        let n = self.seeds.len();
        let workers = self.threads.min(n.max(1));
        let mut slots: Vec<Option<Result<ClusterResult>>> =
            (0..n).map(|_| None).collect();

        if workers <= 1 {
            for (i, &seed) in self.seeds.iter().enumerate() {
                slots[i] = Some(self.run_one(seed));
            }
        } else {
            let next = AtomicUsize::new(0);
            std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(workers);
                for _ in 0..workers {
                    let next = &next;
                    handles.push(scope.spawn(move || {
                        let mut local: Vec<(usize, Result<ClusterResult>)> =
                            Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            local.push((i, self.run_one(self.seeds[i])));
                        }
                        local
                    }));
                }
                for h in handles {
                    // spoton-lint: allow(D3, reason = "a panicked worker is a bug; re-raise it")
                    for (i, r) in h.join().expect("cluster sweep worker panicked")
                    {
                        slots[i] = Some(r);
                    }
                }
            });
        }

        self.seeds
            .iter()
            .zip(slots)
            .map(|(&seed, slot)| {
                // spoton-lint: allow(D3, reason = "the plan visits every index exactly once")
                slot.expect("every seed index visited exactly once")
                    .map(|result| SeededClusterRun { seed, result })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simclock::SimDuration;

    fn contended(jobs: usize, capacity: u32) -> Experiment {
        let mut exp = Experiment::table1()
            .named("cluster-unit")
            .scale_stages(0.02)
            .eviction_poisson(SimDuration::from_mins(40))
            .transparent(SimDuration::from_mins(10))
            .deadline(SimDuration::from_hours(400));
        exp.cfg.cluster =
            Some(ClusterCfg::with_count(jobs).capacity(capacity));
        exp
    }

    #[test]
    fn contended_batch_queues_and_completes_all_jobs() {
        let r = contended(6, 2).run_cluster_sleeper().unwrap();
        assert_eq!(r.jobs.len(), 6);
        assert_eq!(r.completed_jobs(), 6, "{}", r.summary());
        // 6 jobs on 2 slots: at least 4 had to queue at submission
        assert!(r.queued_admissions() >= 4, "{}", r.summary());
        assert_eq!(
            r.timeline.count(EventKind::CapacityExhausted),
            r.timeline.count(EventKind::JobQueued),
            "every CapacityExhausted must be followed by a JobQueued"
        );
        assert_eq!(r.timeline.count(EventKind::JobSubmitted), 6);
        assert_eq!(r.timeline.count(EventKind::JobFinished), 6);
        // jobs genuinely interleave, but never beyond capacity
        assert_eq!(r.peak_in_flight, 2);
        assert_eq!(r.peak_in_flight_per_pool, vec![2]);
        assert!(r.timeline.is_monotone());
        // queued jobs really waited
        let waited = r
            .jobs
            .iter()
            .filter(|j| !j.wait().is_zero())
            .count();
        assert!(waited >= 4, "{waited} jobs waited");
        for j in &r.jobs {
            assert!(j.result.completed, "{}", j.name);
            assert!(j.result.timeline.is_monotone(), "{}", j.name);
        }
    }

    #[test]
    fn same_priority_admits_fifo_lower_priority_number_first() {
        // 1 slot, 4 jobs, no evictions (each job holds the slot to
        // completion, so the admission order is exactly the queue
        // discipline): job 0 takes the free slot, 1..3 queue. Job 3 gets
        // priority 0 (highest), the rest 1 — it must be admitted first
        // even though it queued last; 1 and 2 follow FIFO.
        let mut exp = Experiment::table1()
            .named("cluster-prio")
            .scale_stages(0.02)
            .transparent(SimDuration::from_mins(10))
            .deadline(SimDuration::from_hours(400));
        exp.cfg.cluster = Some(
            ClusterCfg::with_count(4)
                .capacity(1)
                .priorities(vec![1, 1, 1, 0]),
        );
        let r = exp.run_cluster_sleeper().unwrap();
        assert_eq!(r.completed_jobs(), 4);
        let admitted: Vec<&str> = r
            .timeline
            .events()
            .iter()
            .filter(|e| e.kind == EventKind::JobAdmitted)
            .map(|e| e.detail.split(' ').next().unwrap())
            .collect();
        assert_eq!(
            admitted,
            ["job-3", "job-1", "job-2"],
            "priority 0 first, then FIFO within priority 1"
        );
    }

    #[test]
    fn cluster_runs_are_deterministic_per_seed() {
        // Stormy enough that evictions certainly land inside each job's
        // runtime (~37 min of work vs a 10-min poisson mean), so the
        // seed genuinely shapes the run.
        let stormy = |seed: u64| {
            let mut exp = Experiment::table1()
                .named("cluster-det")
                .scale_stages(0.2)
                .eviction_poisson(SimDuration::from_mins(10))
                .transparent(SimDuration::from_mins(10))
                .deadline(SimDuration::from_hours(400))
                .seed(seed);
            exp.cfg.cluster = Some(ClusterCfg::with_count(3).capacity(2));
            exp.run_cluster_sleeper().unwrap()
        };
        let a = stormy(1234);
        assert!(
            a.jobs.iter().any(|j| j.result.evictions > 0),
            "storm must actually evict: {}",
            a.summary()
        );
        assert_eq!(cluster_digest(&a), cluster_digest(&stormy(1234)));
        assert_ne!(
            cluster_digest(&a),
            cluster_digest(&stormy(1235)),
            "seed must matter under poisson evictions"
        );
    }

    #[test]
    fn poisson_arrivals_are_deterministic_and_ordered() {
        let ccfg = ClusterCfg::with_count(8).arrival(ArrivalCfg::Poisson {
            mean: SimDuration::from_mins(3),
        });
        let a = arrival_times(&ccfg, 7);
        let b = arrival_times(&ccfg, 7);
        assert_eq!(a, b);
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "arrivals sorted");
        assert!(a[0] > SimTime::ZERO);
        let c = arrival_times(&ccfg, 8);
        assert_ne!(a, c, "seed drives arrivals");
        // uniform spacing is exact
        let u = ClusterCfg::with_count(3).arrival(ArrivalCfg::Uniform {
            spacing: SimDuration::from_mins(5),
        });
        assert_eq!(
            arrival_times(&u, 0),
            vec![
                SimTime::ZERO,
                SimTime::ZERO + SimDuration::from_mins(5),
                SimTime::ZERO + SimDuration::from_mins(10),
            ]
        );
    }

    #[test]
    fn factory_count_must_match_job_count() {
        let exp = contended(3, 1);
        let err = ClusterEngine::new(&exp.cfg, vec![]).unwrap_err();
        assert!(err.to_string().contains("3 job(s)"), "{err}");
    }
}
