//! Experiment presets and the builder API over [`super::SimDriver`].
//!
//! `Experiment::table1()` carries the paper's testbed defaults
//! (D8s_v3, $0.076/h spot, Azure Files NFS, 30 s notice, Table I row-1
//! stage calibration); builder methods dial in each row's eviction plan
//! and checkpoint method. `run_sleeper` executes with the pure-Rust
//! calibration workload (fast; used by unit tests and the wide ablation
//! sweeps), `run_minimeta` with the PJRT-backed assembler (the real
//! three-layer stack; used by the headline benches and examples).

pub use crate::config::{
    CheckpointMethodCfg, EvictionPlanCfg, IntervalControllerCfg,
    PlacementPolicyCfg, PoolCfg, PoolPricingCfg,
};
use crate::config::ScenarioConfig;
use crate::runtime::Runtime;
use crate::sim::{RunResult, SimDriver};
use crate::simclock::SimDuration;
use crate::storage::{BlobStore, NfsStore, SharedStore, TransferModel};
use crate::workload::assembler::{MiniMeta, MiniMetaCfg};
use crate::workload::sleeper::{Sleeper, SleeperCfg};
use crate::workload::Workload;
use anyhow::Result;
use std::cell::RefCell;
use std::rc::Rc;

/// A configured experiment, ready to run.
#[derive(Debug, Clone)]
pub struct Experiment {
    pub cfg: ScenarioConfig,
}

impl Experiment {
    /// Paper testbed defaults (Table I row 1 calibration, no evictions,
    /// no checkpoints, coordinator attached).
    pub fn table1() -> Self {
        Self { cfg: ScenarioConfig::default() }
    }

    pub fn named(mut self, name: &str) -> Self {
        self.cfg.name = name.to_string();
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    /// Row 1: no coordinator at all.
    pub fn spoton_off(mut self) -> Self {
        self.cfg.coordinator_attached = false;
        self
    }

    /// Run on on-demand pricing (no spot semantics).
    pub fn ondemand(mut self) -> Self {
        self.cfg.cloud.spot = false;
        self.cfg.eviction = EvictionPlanCfg::None;
        self
    }

    /// Inject an eviction every `interval` of instance uptime (the
    /// paper's `simulate-eviction` schedule).
    pub fn eviction_every(mut self, interval: SimDuration) -> Self {
        self.cfg.eviction = EvictionPlanCfg::Fixed { interval };
        self
    }

    /// Poisson spot-market evictions with the given mean inter-arrival.
    pub fn eviction_poisson(mut self, mean: SimDuration) -> Self {
        self.cfg.eviction = EvictionPlanCfg::Poisson { mean };
        self
    }

    /// Replay an empirical eviction trace (uptime offsets per instance).
    pub fn eviction_trace(mut self, offsets: Vec<SimDuration>) -> Self {
        self.cfg.eviction = EvictionPlanCfg::Trace { offsets };
        self
    }

    /// Transparent (CRIU-analog) checkpointing at `interval`.
    pub fn transparent(mut self, interval: SimDuration) -> Self {
        self.cfg.checkpoint = CheckpointMethodCfg::Transparent { interval };
        self
    }

    /// Application-native (metaSPAdes-style) checkpointing.
    pub fn app_native(mut self) -> Self {
        self.cfg.checkpoint = CheckpointMethodCfg::AppNative;
        self
    }

    /// No checkpoint protection.
    pub fn unprotected(mut self) -> Self {
        self.cfg.checkpoint = CheckpointMethodCfg::None;
        self
    }

    /// Compress the termination checkpoint when the raw image would miss
    /// the notice window (`checkpoint::compress` rescue path).
    pub fn compress_termination(mut self, on: bool) -> Self {
        self.cfg.compress_termination = on;
        self
    }

    /// Adaptive checkpoint-interval controller ([`crate::policy`]) tuning
    /// the transparent cadence online; the default
    /// [`IntervalControllerCfg::Fixed`] keeps the configured interval.
    pub fn adaptive(mut self, cfg: IntervalControllerCfg) -> Self {
        self.cfg.adaptive = cfg;
        self
    }

    /// Add a replacement pool to the fleet. The first call switches the
    /// run from the implicit single pool (derived from the `cloud` +
    /// `eviction` config) to the explicit pool list; pool order fixes
    /// pool ids and per-pool eviction seeds.
    pub fn pool(mut self, pool: PoolCfg) -> Self {
        self.cfg.fleet.pools.push(pool);
        self
    }

    /// Placement policy deciding which pool each replacement lands in.
    pub fn placement(mut self, policy: PlacementPolicyCfg) -> Self {
        self.cfg.fleet.placement = policy;
        self
    }

    pub fn deadline(mut self, d: SimDuration) -> Self {
        self.cfg.deadline = d;
        self
    }

    /// Timeline recording level ([`crate::metrics::RecordLevel::Counts`]
    /// skips per-event records — the sweep/bench hot path).
    pub fn metrics(mut self, level: crate::metrics::RecordLevel) -> Self {
        self.cfg.metrics = level;
        self
    }

    pub fn notice(mut self, d: SimDuration) -> Self {
        self.cfg.cloud.notice = d;
        self
    }

    pub fn state_gib(mut self, gib: f64) -> Self {
        self.cfg.workload.state_gib = gib;
        self
    }

    pub fn nfs_bandwidth(mut self, mib_s: f64) -> Self {
        self.cfg.storage.bandwidth_mib_s = mib_s;
        self
    }

    pub fn app_milestones(mut self, per_stage: u32) -> Self {
        self.cfg.workload.app_milestones_per_stage = per_stage;
        self
    }

    /// Scale the workload's *calibrated* stage durations (for fast test
    /// runs) without touching eviction/checkpoint intervals.
    pub fn scale_stages(mut self, factor: f64) -> Self {
        for s in &mut self.cfg.workload.stage_secs {
            *s = ((*s as f64) * factor).round().max(1.0) as u64;
        }
        self
    }

    fn transfer_model(&self) -> TransferModel {
        TransferModel {
            bandwidth_mib_s: self.cfg.storage.bandwidth_mib_s,
            latency: self.cfg.storage.latency,
        }
    }

    /// A fresh in-memory share built from this scenario's storage config —
    /// exactly the store [`Experiment::run_with_factory`] runs against.
    /// Exposed so the equivalence oracle, benches and the requeue
    /// scheduler construct byte-identical substrates instead of
    /// re-deriving the transfer model by hand.
    pub fn fresh_store(&self) -> BlobStore {
        BlobStore::new(
            self.transfer_model(),
            Some(self.cfg.storage.provisioned_gib),
        )
    }

    fn sleeper_cfg(&self) -> SleeperCfg {
        let w = &self.cfg.workload;
        SleeperCfg {
            stages: w
                .ks
                .iter()
                .map(|k| (format!("K{k}"), 40u64))
                .collect(),
            milestones_per_stage: w.app_milestones_per_stage,
            charged_bytes: (w.state_gib * (1u64 << 30) as f64) as u64,
            app_charged_bytes: (w.app_ckpt_gib * (1u64 << 30) as f64) as u64,
        }
    }

    fn minimeta_cfg(&self) -> MiniMetaCfg {
        let w = &self.cfg.workload;
        MiniMetaCfg {
            total_reads: w.total_reads,
            denoise_sweeps: w.denoise_sweeps,
            milestones_per_stage: w.app_milestones_per_stage,
            charged_bytes: (w.state_gib * (1u64 << 30) as f64) as u64,
            app_charged_bytes: (w.app_ckpt_gib * (1u64 << 30) as f64) as u64,
            seed: w.seed,
            base_threshold: 2.0,
        }
    }

    /// Run with any workload factory against an in-memory share.
    pub fn run_with_factory(
        &self,
        factory: &mut dyn FnMut() -> Result<Box<dyn Workload>>,
    ) -> Result<RunResult> {
        let mut store = self.fresh_store();
        SimDriver::new(&self.cfg, &mut store).run(factory)
    }

    /// Run with a workload factory against a real directory-backed NFS
    /// share (integration tests / CLI).
    pub fn run_with_factory_on_store(
        &self,
        store: &mut dyn SharedStore,
        factory: &mut dyn FnMut() -> Result<Box<dyn Workload>>,
    ) -> Result<RunResult> {
        SimDriver::new(&self.cfg, store).run(factory)
    }

    /// Fast run with the pure-Rust sleeper workload.
    pub fn run_sleeper(&self) -> Result<RunResult> {
        let cfg = self.sleeper_cfg();
        let seed = self.cfg.workload.seed;
        self.run_with_factory(&mut || {
            Ok(Box::new(Sleeper::new(cfg.clone(), seed)))
        })
    }

    /// Full three-layer run with the PJRT-backed MiniMeta assembler.
    pub fn run_minimeta(&self, rt: Rc<RefCell<Runtime>>) -> Result<RunResult> {
        let cfg = self.minimeta_cfg();
        self.run_with_factory(&mut || {
            Ok(Box::new(MiniMeta::new(cfg.clone(), rt.clone())?))
        })
    }

    /// MiniMeta run against a directory-backed share.
    pub fn run_minimeta_on_nfs(
        &self,
        rt: Rc<RefCell<Runtime>>,
        root: &std::path::Path,
    ) -> Result<RunResult> {
        let mut store = NfsStore::open(
            root,
            self.transfer_model(),
            Some(self.cfg.storage.provisioned_gib),
        )?;
        let cfg = self.minimeta_cfg();
        SimDriver::new(&self.cfg, &mut store).run(&mut || {
            Ok(Box::new(MiniMeta::new(cfg.clone(), rt.clone())?))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::EventKind;

    #[test]
    fn baseline_matches_calibration() {
        // Row 1: Spot-on OFF, no evictions — total must equal the
        // calibrated stage durations exactly.
        let r = Experiment::table1()
            .named("row1")
            .spoton_off()
            .run_sleeper()
            .unwrap();
        assert!(r.completed);
        assert_eq!(r.total.hms(), "3:03:26");
        assert_eq!(r.evictions, 0);
        assert_eq!(r.instances, 1);
        let expected = ["33:50", "38:53", "39:51", "40:19", "30:33"];
        for ((label, d), want) in r.stage_times.iter().zip(expected) {
            assert_eq!(d.hms(), want, "{label}");
        }
    }

    #[test]
    fn coordinator_overhead_is_small() {
        // Row 2: ON, no ckpt, no evictions — ~1.1% overhead.
        let r1 = Experiment::table1().spoton_off().run_sleeper().unwrap();
        let r2 = Experiment::table1().run_sleeper().unwrap();
        let ratio =
            r2.total.as_millis() as f64 / r1.total.as_millis() as f64 - 1.0;
        assert!(
            (0.005..0.02).contains(&ratio),
            "overhead ratio {ratio}"
        );
    }

    #[test]
    fn transparent_90_30_completes_near_baseline() {
        // Row 5 analog: eviction every 90 min, transparent every 30 min.
        let r = Experiment::table1()
            .eviction_every(SimDuration::from_mins(90))
            .transparent(SimDuration::from_mins(30))
            .run_sleeper()
            .unwrap();
        assert!(r.completed);
        assert_eq!(r.evictions, 2, "3h run with 90min evictions");
        assert_eq!(r.instances, 3);
        assert!(r.termination_ok >= 1, "termination ckpts should commit");
        assert_eq!(r.termination_failed, 0);
        assert!(r.periodic_ckpts >= 4);
        // within ~8% of baseline (paper: within noise)
        let baseline = 11006.0;
        let total = r.total.as_secs() as f64;
        assert!(
            total < baseline * 1.08,
            "transparent total {} too far above baseline",
            r.total
        );
        assert!(r.timeline.is_monotone());
    }

    #[test]
    fn app_native_loses_more_time_than_transparent() {
        let app = Experiment::table1()
            .eviction_every(SimDuration::from_mins(60))
            .app_native()
            .run_sleeper()
            .unwrap();
        let tr = Experiment::table1()
            .eviction_every(SimDuration::from_mins(60))
            .transparent(SimDuration::from_mins(30))
            .run_sleeper()
            .unwrap();
        assert!(app.completed && tr.completed);
        assert!(
            app.total > tr.total,
            "app {} must exceed transparent {}",
            app.total,
            tr.total
        );
        assert!(app.lost_steps > tr.lost_steps);
        // paper Fig 3: transparent saves 15-40% under frequent evictions;
        // accept a broad 5-45% band for the sleeper calibration
        let saving =
            1.0 - tr.total.as_millis() as f64 / app.total.as_millis() as f64;
        assert!(
            (0.05..0.45).contains(&saving),
            "transparent saving {saving} out of plausible band"
        );
    }

    #[test]
    fn unprotected_run_restarts_from_zero() {
        // without checkpoints, each eviction loses everything so far
        let r = Experiment::table1()
            .named("unprotected")
            .eviction_every(SimDuration::from_mins(100))
            .unprotected()
            .deadline(SimDuration::from_hours(9))
            .run_sleeper()
            .unwrap();
        // 3h3m of work restarting every 100min of uptime: never finishes
        assert!(!r.completed, "unprotected run should starve: {}", r.summary());
        assert!(r.lost_steps > 0);
        assert!(r.timeline.count(EventKind::Aborted) == 1);
    }

    #[test]
    fn spot_cost_is_much_cheaper_than_ondemand() {
        let od = Experiment::table1()
            .spoton_off()
            .ondemand()
            .run_sleeper()
            .unwrap();
        let spot = Experiment::table1()
            .eviction_every(SimDuration::from_mins(90))
            .transparent(SimDuration::from_mins(30))
            .run_sleeper()
            .unwrap();
        assert!(od.completed && spot.completed);
        let saving = 1.0 - spot.total_cost() / od.total_cost();
        // paper Fig 2: ~77% (price cut + overheads + NFS)
        assert!(
            (0.70..0.85).contains(&saving),
            "cost saving {saving:.3}, od ${:.4}, spot ${:.4}",
            od.total_cost(),
            spot.total_cost()
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            Experiment::table1()
                .eviction_poisson(SimDuration::from_mins(75))
                .transparent(SimDuration::from_mins(15))
                .seed(33)
                .run_sleeper()
                .unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.total, b.total);
        assert_eq!(a.evictions, b.evictions);
        assert_eq!(a.final_fingerprint, b.final_fingerprint);
        assert_eq!(a.timeline.events().len(), b.timeline.events().len());
    }

    #[test]
    fn resumed_state_matches_uninterrupted_state() {
        // the headline correctness invariant: with transparent ckpts, the
        // final workload state equals an uninterrupted run's state
        let base = Experiment::table1().spoton_off().run_sleeper().unwrap();
        let evicted = Experiment::table1()
            .eviction_every(SimDuration::from_mins(45))
            .transparent(SimDuration::from_mins(15))
            .run_sleeper()
            .unwrap();
        assert!(evicted.completed);
        assert!(evicted.evictions >= 2);
        assert_eq!(
            base.final_fingerprint, evicted.final_fingerprint,
            "resume diverged from uninterrupted execution"
        );
    }

    #[test]
    fn short_notice_fails_termination_checkpoint() {
        // 3 GiB at 250 MiB/s needs ~12.3s; a 5s notice cannot fit
        let r = Experiment::table1()
            .eviction_every(SimDuration::from_mins(90))
            .transparent(SimDuration::from_mins(30))
            .notice(SimDuration::from_secs(5))
            .run_sleeper()
            .unwrap();
        assert!(r.completed);
        assert!(r.termination_failed >= 1, "{}", r.summary());
        assert_eq!(r.termination_ok, 0);
        // still completes via periodic checkpoints, just loses more
        assert!(r.total.as_secs() > 11006);
    }
}
