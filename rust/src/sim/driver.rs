//! The experiment driver: public facade over the event-driven engine.
//!
//! One [`SimDriver::run`] call = one paper experiment: launch a spot
//! instance through the scale set, attach the coordinator, drive the
//! workload, inject evictions per the plan, take checkpoints per the
//! policy, replace evicted instances, restore, and keep going until the
//! workload completes (or the scenario deadline proves it never will —
//! paper §IV's starvation case).
//!
//! Since the event-core refactor the actual mechanics live in
//! [`super::engine`]: a discrete-event loop over `simclock::EventQueue`
//! where compute steps, checkpoint transfers, eviction notices, poll
//! ticks and provisioning completions are typed [`super::engine::SimEvent`]s.
//! This module keeps the stable API every bench, test and example uses —
//! [`RunResult`] and the `SimDriver` entry point — and documents the time
//! accounting contract below.
//!
//! ## Time accounting
//!
//! * compute: each workload step costs
//!   `stage_secs[stage] / stage_steps(stage)` virtual seconds, scaled by
//!   `1 + coordinator_overhead` when Spot-on is attached (Table I rows
//!   1→2 delta);
//! * checkpoints: the workload freezes for the modeled transfer time of
//!   the snapshot's charged size (CRIU dump / app checkpoint file write);
//! * eviction: the notice posts at the plan's uptime offset; the
//!   coordinator detects it at its next scheduled-events poll tick; a
//!   transparent termination checkpoint races `NotBefore`; the instance
//!   dies, the scale set provisions a replacement (a scheduled event, not
//!   a blocking wait), the coordinator restores from the most recent
//!   valid checkpoint.
//!
//! The legacy imperative loop these semantics came from survives verbatim
//! in [`super::legacy`] as the oracle for the equivalence suite.

use super::engine::Engine;
use crate::cloud::billing::Invoice;
use crate::config::ScenarioConfig;
use crate::metrics::Timeline;
use crate::simclock::SimDuration;
use crate::storage::SharedStore;
use crate::workload::Workload;
use anyhow::Result;

/// Everything one run produced.
#[derive(Debug)]
pub struct RunResult {
    pub scenario: String,
    pub completed: bool,
    /// (stage label, wall duration) — final completion times, so re-done
    /// work lands in the stage where it was re-done (what the paper's
    /// per-k columns report).
    pub stage_times: Vec<(String, SimDuration)>,
    pub total: SimDuration,
    pub notices: u32,
    pub evictions: u32,
    pub instances: u32,
    pub periodic_ckpts: u32,
    pub termination_ok: u32,
    pub termination_failed: u32,
    pub app_ckpts: u32,
    pub restores: u32,
    /// Workload steps lost to evictions (re-executed after restore).
    pub lost_steps: u64,
    pub compute_cost: f64,
    pub storage_cost: f64,
    pub invoice: Invoice,
    pub timeline: Timeline,
    pub final_fingerprint: u64,
}

impl RunResult {
    /// One-line summary.
    pub fn summary(&self) -> String {
        format!(
            "{}: {} in {} | {} eviction(s), {} instance(s), ckpts: {}p/{}t(+{}f)/{}a, \
             {} restore(s), {} steps lost | compute {} + storage {}",
            self.scenario,
            if self.completed { "completed" } else { "DID NOT FINISH" },
            self.total,
            self.evictions,
            self.instances,
            self.periodic_ckpts,
            self.termination_ok,
            self.termination_failed,
            self.app_ckpts,
            self.restores,
            self.lost_steps,
            crate::util::fmt::dollars(self.compute_cost),
            crate::util::fmt::dollars(self.storage_cost),
        )
    }

    pub fn total_cost(&self) -> f64 {
        self.compute_cost + self.storage_cost
    }

    /// Stage duration by label.
    pub fn stage(&self, label: &str) -> Option<SimDuration> {
        self.stage_times
            .iter()
            .find(|(l, _)| l == label)
            .map(|(_, d)| *d)
    }
}

/// The driver. Owns the cloud, the share and the coordinator state;
/// borrows a workload factory (fresh starts after unprotected evictions
/// need a brand-new workload).
pub struct SimDriver<'a> {
    cfg: &'a ScenarioConfig,
    store: &'a mut dyn SharedStore,
}

impl<'a> SimDriver<'a> {
    pub fn new(cfg: &'a ScenarioConfig, store: &'a mut dyn SharedStore) -> Self {
        Self { cfg, store }
    }

    /// Run the scenario on the event engine. `factory` builds a fresh
    /// workload (used at start and when an unprotected run must restart
    /// from zero).
    pub fn run(
        &mut self,
        factory: &mut dyn FnMut() -> Result<Box<dyn Workload>>,
    ) -> Result<RunResult> {
        Engine::new(self.cfg, self.store, factory)?.run()
    }
}
