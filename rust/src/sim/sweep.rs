//! Parallel Monte Carlo sweep driver: thousands of seeded runs, merged
//! deterministically.
//!
//! The paper's headline numbers (Table I runtimes, Fig 2 cost, Fig 3
//! completion time) are point estimates from single eviction schedules.
//! This module turns the ms-per-run event engine into a population-scale
//! evaluator: a [`Sweep`] fans one base [`Experiment`] across a seed
//! list on `std::thread` workers — one engine + one fresh store per run,
//! **no shared mutable state** beyond an atomic work index — and merges
//! the [`RunResult`]s back *by seed position*, so the output vector is
//! byte-identical at any thread count (pinned by
//! `tests/sweep_determinism.rs`). Distribution summaries over the merged
//! vector live in [`crate::report::distribution`].
//!
//! Sweeps default to [`RecordLevel::Counts`]: the per-event timeline
//! (detail `String`s, event `Vec` growth) is skipped and only per-kind
//! counters are kept, which is most of the difference between a
//! "row-per-run" single experiment and the sweep's per-run mean (see
//! `benches/sweep_montecarlo.rs`). Runs are deterministic per seed even
//! at [`RecordLevel::Full`] — event ids are per-metadata-service, not
//! process-global — so timeline-carrying sweeps merge byte-identically
//! too, just slower.
//!
//! ```no_run
//! use spoton::sim::experiment::Experiment;
//! use spoton::simclock::SimDuration;
//!
//! let runs = Experiment::table1()
//!     .eviction_poisson(SimDuration::from_mins(75))
//!     .transparent(SimDuration::from_mins(15))
//!     .sweep()
//!     .seed_range(0, 10_000)
//!     .threads(8)
//!     .run()
//!     .unwrap();
//! let dist = spoton::report::distribution::summarize("poisson-75", &runs);
//! println!("{}", spoton::report::distribution::render(&dist));
//! ```

use super::experiment::Experiment;
use super::RunResult;
use crate::config::IntervalControllerCfg;
use crate::metrics::RecordLevel;
use anyhow::Result;
use std::sync::atomic::{AtomicUsize, Ordering};

/// One merged sweep entry: the scenario seed and everything its run
/// produced.
#[derive(Debug)]
pub struct SeededRun {
    pub seed: u64,
    pub result: RunResult,
}

/// A configured Monte Carlo sweep over one base experiment.
#[derive(Debug, Clone)]
pub struct Sweep {
    base: Experiment,
    seeds: Vec<u64>,
    threads: usize,
    record: RecordLevel,
}

impl Experiment {
    /// Start a sweep over this experiment (seeds override the scenario
    /// seed run by run; everything else is shared).
    pub fn sweep(&self) -> Sweep {
        Sweep::new(self.clone())
    }
}

impl Sweep {
    /// A sweep with no seeds yet, one worker per available core, and the
    /// lean [`RecordLevel::Counts`] metrics level.
    pub fn new(base: Experiment) -> Self {
        Self {
            base,
            seeds: Vec::new(),
            // spoton-lint: allow(D2, reason = "worker-count default only; merged results are seed-keyed")
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            record: RecordLevel::Counts,
        }
    }

    /// Explicit seed list (merge order == this order).
    pub fn seeds(mut self, seeds: impl IntoIterator<Item = u64>) -> Self {
        self.seeds = seeds.into_iter().collect();
        self
    }

    /// The contiguous seed range `first .. first + n`.
    pub fn seed_range(self, first: u64, n: usize) -> Self {
        let seeds: Vec<u64> =
            (0..n as u64).map(|i| first.wrapping_add(i)).collect();
        self.seeds(seeds)
    }

    /// Worker thread count (clamped to at least 1; 1 runs inline without
    /// spawning).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    /// Timeline recording level for every run (default
    /// [`RecordLevel::Counts`]; use [`RecordLevel::Full`] when the
    /// per-run timelines are the point of the sweep).
    pub fn record(mut self, level: RecordLevel) -> Self {
        self.record = level;
        self
    }

    pub fn seed_count(&self) -> usize {
        self.seeds.len()
    }

    /// One run at `seed`, exactly as the sweep executes it (exposed so
    /// single-run baselines in benches measure the identical path).
    pub fn run_one(&self, seed: u64) -> Result<RunResult> {
        let mut exp = self.base.clone().seed(seed);
        exp.cfg.metrics = self.record;
        exp.run_sleeper()
    }

    /// Run every seed and merge the results by seed position.
    ///
    /// Workers pull indices from a shared atomic counter (so a straggler
    /// run never idles the other threads) and stash `(index, result)`
    /// pairs locally; the merge writes each result into its seed's slot
    /// after joining. Which worker ran which seed is scheduling noise —
    /// the merged vector never reflects it. The first run error (in seed
    /// order) aborts the sweep.
    pub fn run(&self) -> Result<Vec<SeededRun>> {
        let n = self.seeds.len();
        let workers = self.threads.min(n.max(1));
        let mut slots: Vec<Option<Result<RunResult>>> =
            (0..n).map(|_| None).collect();

        if workers <= 1 {
            for (i, &seed) in self.seeds.iter().enumerate() {
                slots[i] = Some(self.run_one(seed));
            }
        } else {
            let next = AtomicUsize::new(0);
            std::thread::scope(|scope| {
                let mut handles = Vec::with_capacity(workers);
                for _ in 0..workers {
                    let next = &next;
                    handles.push(scope.spawn(move || {
                        let mut local: Vec<(usize, Result<RunResult>)> =
                            Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            local.push((i, self.run_one(self.seeds[i])));
                        }
                        local
                    }));
                }
                for h in handles {
                    // spoton-lint: allow(D3, reason = "a panicked worker is a bug; re-raise it")
                    for (i, r) in h.join().expect("sweep worker panicked") {
                        slots[i] = Some(r);
                    }
                }
            });
        }

        self.seeds
            .iter()
            .zip(slots)
            .map(|(&seed, slot)| {
                // spoton-lint: allow(D3, reason = "the plan visits every index exactly once")
                slot.expect("every seed index visited exactly once")
                    .map(|result| SeededRun { seed, result })
            })
            .collect()
    }
}

/// One interval controller's merged sweep: the controller label and the
/// full seed-ordered population it produced.
#[derive(Debug)]
pub struct ControllerSweep {
    pub label: String,
    pub runs: Vec<SeededRun>,
}

impl Sweep {
    /// Run the same seed list once per interval controller — the
    /// controller analogue of sweeping placement policies: every entry
    /// reruns the base experiment with only `[checkpoint.adaptive]`
    /// swapped, so the merged populations differ by the controller and
    /// nothing else. Output order follows `controllers`; each entry's
    /// runs merge by seed position exactly like [`Sweep::run`], so the
    /// whole comparison is byte-identical at any thread count.
    pub fn run_controllers(
        &self,
        controllers: &[IntervalControllerCfg],
    ) -> Result<Vec<ControllerSweep>> {
        controllers
            .iter()
            .map(|cfg| {
                let mut sweep = self.clone();
                sweep.base.cfg.adaptive = cfg.clone();
                Ok(ControllerSweep { label: cfg.label(), runs: sweep.run()? })
            })
            .collect()
    }
}

/// Canonical digest of everything a run produced — every `RunResult`
/// field (costs bitwise), per-pool attribution, and the full timeline.
/// Two runs are byte-identical iff their digests match; the determinism
/// suite compares digest vectors across thread counts.
pub fn run_digest(r: &RunResult) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = write!(
        out,
        "{}|completed={}|total={}|notices={}|evictions={}|instances={}|\
         ckpts={}p/{}t/{}f/{}a|restores={}|lost={}|compute={:016x}|\
         storage={:016x}|fp={:016x}",
        r.scenario,
        r.completed,
        r.total.as_millis(),
        r.notices,
        r.evictions,
        r.instances,
        r.periodic_ckpts,
        r.termination_ok,
        r.termination_failed,
        r.app_ckpts,
        r.restores,
        r.lost_steps,
        r.compute_cost.to_bits(),
        r.storage_cost.to_bits(),
        r.final_fingerprint,
    );
    // Gated like the bid/autoscale event kinds: deadline-free scenarios
    // (deadline_missed == None) keep their pre-SLA digest bytes.
    if let Some(missed) = r.deadline_missed {
        let _ = write!(out, "|deadline_missed={missed}");
    }
    for (label, d) in &r.stage_times {
        let _ = write!(out, "|stage:{label}={}", d.as_millis());
    }
    for p in &r.pool_stats {
        let _ = write!(
            out,
            "|pool:{}={}l/{}e/{:016x}",
            p.pool,
            p.launches,
            p.evictions,
            p.compute_cost.to_bits()
        );
    }
    // Per-kind counters are the only timeline data a Counts-level run
    // keeps — they must enter the digest for the iff contract to hold.
    // Chaos and bid/autoscale kinds are gated on being observed: a run
    // that never sees them keeps a digest byte-identical to digests
    // minted before those kinds existed, while any injected fault or
    // outbid still lands in the digest.
    for k in crate::metrics::EventKind::ALL {
        if k.is_digest_gated() && r.timeline.count(k) == 0 {
            continue;
        }
        let _ = write!(out, "|#{}={}", k.as_str(), r.timeline.count(k));
    }
    for e in r.timeline.events() {
        let _ = write!(
            out,
            "|{}@{}:{}",
            e.kind.as_str(),
            e.at.as_millis(),
            e.detail
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simclock::SimDuration;

    fn base() -> Experiment {
        Experiment::table1()
            .named("sweep-unit")
            .eviction_poisson(SimDuration::from_mins(60))
            .transparent(SimDuration::from_mins(20))
    }

    #[test]
    fn merged_order_follows_seed_list() {
        let runs = base().sweep().seeds([9, 2, 7]).threads(2).run().unwrap();
        let seeds: Vec<u64> = runs.iter().map(|r| r.seed).collect();
        assert_eq!(seeds, [9, 2, 7]);
    }

    #[test]
    fn empty_sweep_is_empty() {
        assert!(base().sweep().run().unwrap().is_empty());
    }

    #[test]
    fn sweep_runs_default_to_counts_level() {
        let runs = base().sweep().seeds([5]).threads(1).run().unwrap();
        let r = &runs[0].result;
        assert!(r.completed);
        assert!(
            r.timeline.events().is_empty(),
            "Counts level must not keep timeline events"
        );
        // counters still work
        assert_eq!(
            r.timeline.count(crate::metrics::EventKind::InstanceEvicted),
            r.evictions as usize
        );
    }

    #[test]
    fn controller_sweeps_share_seeds_and_differ_by_controller() {
        let sweeps = base()
            .sweep()
            .seeds([1, 2])
            .threads(1)
            .run_controllers(&[
                IntervalControllerCfg::Fixed,
                IntervalControllerCfg::young_daly(),
            ])
            .unwrap();
        assert_eq!(sweeps.len(), 2);
        assert_eq!(sweeps[0].label, "fixed");
        assert_eq!(sweeps[1].label, "young-daly");
        for s in &sweeps {
            let seeds: Vec<u64> = s.runs.iter().map(|r| r.seed).collect();
            assert_eq!(seeds, [1, 2], "{}: seed lists must match", s.label);
        }
        // on a stormy base the controller really changes the run
        assert_ne!(
            run_digest(&sweeps[0].runs[0].result),
            run_digest(&sweeps[1].runs[0].result),
            "young-daly must deviate from fixed under evictions"
        );
    }

    #[test]
    fn run_one_matches_sweep_entry() {
        let sweep = base().sweep().seeds([33]).threads(1);
        let solo = sweep.run_one(33).unwrap();
        let merged = sweep.run().unwrap();
        assert_eq!(run_digest(&solo), run_digest(&merged[0].result));
    }
}
