//! Seeded fault injection: the per-run `FaultPlan`.
//!
//! Chaos turns the simulator's *planned* evictions into the full failure
//! menagerie a real spot fleet sees — flaky checkpoint storage
//! ([`crate::storage::chaos`]), silently corrupted snapshots, IMDS
//! scheduled-events outages, and coordinated multi-pool eviction storms —
//! while keeping the determinism contract that everything else in the
//! simulator obeys: **every fault instant and probability draw is a
//! function of `(scenario seed, chaos salt)` only**, never thread, worker
//! or shard count, so chaos-enabled sweeps merge byte-identically at any
//! parallelism (`tests/sweep_determinism.rs`).
//!
//! [`FaultPlan`] is the run-level schedule, drawn once up front from a
//! salted PRNG stream: the storm instants (each storm rewrites every live
//! instance's eviction schedule to "now", across all pools at once) and
//! the IMDS outage windows (inside which the monitor cannot see the
//! scheduled-events document and degrades to a slower poll cadence
//! instead of silently losing the notice). Storage-level faults draw from
//! their own per-put stream inside [`crate::storage::chaos::ChaosStore`].
//!
//! # TOML reference
//!
//! ```toml
//! [chaos]
//! salt = 99            # decorrelates this scenario's fault stream
//! storms = 2           # coordinated multi-pool eviction storms
//! window_mins = 120    # storms + outages drawn in [0, window) from start
//!
//! [chaos.storage]
//! write_fail_prob = 0.10    # put dies before any bytes move
//! torn_write_prob = 0.05    # put dies mid-transfer; half the object lands
//! corrupt_prob = 0.05       # payload lands bit-flipped; restore-time
//!                           # CRC/SHA verification catches it
//! latency_spike_prob = 0.2  # put succeeds but costs extra virtual time
//! latency_spike_ms = 1500
//!
//! [chaos.imds]
//! outages = 2               # metadata-endpoint outage windows
//! outage_mins = 2.0
//! degraded_poll_factor = 6  # poll-interval multiplier while down
//!
//! [chaos.market]
//! shocks = 2                # price-shock windows spliced into traces
//! factor = 2.5              # traced factor multiplier inside a window
//! duration_mins = 30.0
//! ```
//!
//! Market shocks are *trace splices*, not runtime events: each window
//! multiplies every traced pool's price factor by `factor` for its
//! duration ([`crate::cloud::trace::splice_price_shocks`]), rewritten
//! into the pools' replay streams before the engine schedules anything.
//! The run then sees ordinary `PoolPriceChanged` events — shocks
//! compose with bids ([`crate::autoscale`]): a shock crossing a bid
//! outbids the instance. Requires at least one traced pool (rejected as
//! inert otherwise).
//!
//! With `[chaos]` absent nothing is armed and every digest is
//! byte-identical to a chaos-free build; an armed plan with all
//! probabilities zero and no storms/outages is observably identical too
//! (draws are consumed internally, never surfaced).

use crate::config::ChaosCfg;
use crate::simclock::{SimDuration, SimTime};
use crate::util::prng::{mix64, Prng};

pub use crate::coordinator::backoff::BACKOFF_SEED_SALT;
pub use crate::storage::chaos::STORAGE_CHAOS_SALT;

/// Salt for the plan-level stream (storm instants, outage windows).
pub const PLAN_SEED_SALT: u64 = 0xC4A0_5F17_0D5E_A7B1;

/// Per-job stride for cluster seeds: job 0 must match the single-run
/// engine exactly (the single-job equivalence pin), later jobs must be
/// decorrelated.
const JOB_STRIDE: u64 = 0x9E37_79B9_7F4A_7C15;

/// Seed for a run's storage-fault stream.
pub fn storage_seed(scenario_seed: u64, chaos_salt: u64) -> u64 {
    mix64(scenario_seed ^ chaos_salt ^ STORAGE_CHAOS_SALT)
}

/// Seed for cluster job `idx`'s storage-fault stream (`idx = 0` equals
/// [`storage_seed`]).
pub fn job_storage_seed(scenario_seed: u64, chaos_salt: u64, idx: u64) -> u64 {
    mix64(
        scenario_seed
            ^ chaos_salt
            ^ STORAGE_CHAOS_SALT
            ^ idx.wrapping_mul(JOB_STRIDE),
    )
}

/// Seed for a run's retry-jitter stream (independent of chaos: the
/// backoff policy exists whether or not faults are injected).
pub fn backoff_seed(scenario_seed: u64) -> u64 {
    mix64(scenario_seed ^ BACKOFF_SEED_SALT)
}

/// Seed for cluster job `idx`'s retry-jitter stream.
pub fn job_backoff_seed(scenario_seed: u64, idx: u64) -> u64 {
    mix64(scenario_seed ^ BACKOFF_SEED_SALT ^ idx.wrapping_mul(JOB_STRIDE))
}

/// The run-level fault schedule, drawn once per run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Storm instants, ascending. At each one every live instance's
    /// eviction schedule is rewritten to post a notice immediately.
    pub storms: Vec<SimTime>,
    /// IMDS outage windows `[start, end)`, ascending by start.
    pub outages: Vec<(SimTime, SimTime)>,
    /// Poll-interval multiplier while inside an outage window.
    pub degraded_poll_factor: u32,
    /// Market price-shock windows `[start, end)` as offsets from run
    /// start, ascending, non-overlapping (merged at draw), never
    /// starting at t = 0 — fed to
    /// [`Fleet::splice_market_shocks`](crate::cloud::fleet::Fleet::splice_market_shocks)
    /// before anything is scheduled.
    pub market_shocks: Vec<(SimDuration, SimDuration)>,
    /// Traced-factor multiplier inside a shock window (1.0 when market
    /// chaos is off).
    pub market_factor: f64,
}

impl FaultPlan {
    /// The empty plan (chaos off): no storms, no outages, no shocks.
    pub fn none() -> Self {
        FaultPlan {
            degraded_poll_factor: 1,
            market_factor: 1.0,
            ..FaultPlan::default()
        }
    }

    /// Draw a plan from the scenario seed. Instants are uniform in
    /// `[0, window)`; the draw order is fixed (storms first, then
    /// outages, then market shocks) so the stream is stable as knobs are
    /// toggled independently of each other.
    pub fn draw(cfg: &ChaosCfg, scenario_seed: u64) -> Self {
        let mut rng =
            Prng::new(mix64(scenario_seed ^ cfg.salt ^ PLAN_SEED_SALT));
        let window_ms = cfg.window.as_millis().max(1);
        let mut storms: Vec<SimTime> = (0..cfg.storms)
            .map(|_| SimTime(rng.below(window_ms)))
            .collect();
        storms.sort_unstable();
        let mut outages: Vec<(SimTime, SimTime)> = (0..cfg.imds.outages)
            .map(|_| {
                let start = SimTime(rng.below(window_ms));
                (start, start + cfg.imds.outage_duration)
            })
            .collect();
        outages.sort_unstable();
        // shock starts clamp to >= 1 ms so the initial price epoch is
        // never rewritten (an offset-0 splice would change placement's
        // very first decision, not just the market's evolution)
        let mut shocks: Vec<(SimDuration, SimDuration)> = (0..cfg
            .market
            .shocks)
            .map(|_| {
                let start =
                    SimDuration::from_millis(rng.below(window_ms).max(1));
                (start, start + cfg.market.duration)
            })
            .collect();
        shocks.sort_unstable();
        // merge overlapping windows so the multiplier applies once
        let mut market_shocks: Vec<(SimDuration, SimDuration)> =
            Vec::with_capacity(shocks.len());
        for (s, e) in shocks {
            match market_shocks.last_mut() {
                Some(last) if s <= last.1 => last.1 = last.1.max(e),
                _ => market_shocks.push((s, e)),
            }
        }
        FaultPlan {
            storms,
            outages,
            degraded_poll_factor: cfg.imds.degraded_poll_factor.max(1),
            market_shocks,
            market_factor: if cfg.market.shocks > 0 {
                cfg.market.factor
            } else {
                1.0
            },
        }
    }

    /// Is the metadata endpoint down at `now`?
    pub fn imds_down(&self, now: SimTime) -> bool {
        self.outages.iter().any(|&(start, end)| start <= now && now < end)
    }

    /// When the current outage ends, if one is active at `now`.
    pub fn outage_ends(&self, now: SimTime) -> Option<SimTime> {
        self.outages
            .iter()
            .filter(|&&(start, end)| start <= now && now < end)
            .map(|&(_, end)| end)
            .max()
    }

    /// The degraded poll interval during an outage.
    pub fn degraded_poll(&self, poll: SimDuration) -> SimDuration {
        SimDuration::from_millis(
            poll.as_millis()
                .saturating_mul(u64::from(self.degraded_poll_factor.max(1))),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ChaosImdsCfg;

    fn storm_cfg() -> ChaosCfg {
        ChaosCfg {
            salt: 3,
            storms: 4,
            window: SimDuration::from_mins(100),
            imds: ChaosImdsCfg {
                outages: 2,
                outage_duration: SimDuration::from_mins(2),
                degraded_poll_factor: 6,
            },
            ..ChaosCfg::default()
        }
    }

    #[test]
    fn plan_is_deterministic_and_seed_sensitive() {
        let cfg = storm_cfg();
        assert_eq!(FaultPlan::draw(&cfg, 7), FaultPlan::draw(&cfg, 7));
        assert_ne!(FaultPlan::draw(&cfg, 7), FaultPlan::draw(&cfg, 8));
        let salted = ChaosCfg { salt: 4, ..cfg.clone() };
        assert_ne!(FaultPlan::draw(&cfg, 7), FaultPlan::draw(&salted, 7));
    }

    #[test]
    fn plan_respects_window_and_sorting() {
        let cfg = storm_cfg();
        let plan = FaultPlan::draw(&cfg, 11);
        assert_eq!(plan.storms.len(), 4);
        assert_eq!(plan.outages.len(), 2);
        for w in plan.storms.windows(2) {
            assert!(w[0] <= w[1]);
        }
        for &t in &plan.storms {
            assert!(t < SimTime::ZERO + cfg.window);
        }
        for &(start, end) in &plan.outages {
            assert!(start < SimTime::ZERO + cfg.window);
            assert_eq!(end, start + cfg.imds.outage_duration);
        }
    }

    #[test]
    fn outage_queries() {
        let plan = FaultPlan {
            outages: vec![(
                SimTime::from_secs(100),
                SimTime::from_secs(220),
            )],
            degraded_poll_factor: 6,
            ..FaultPlan::none()
        };
        assert!(!plan.imds_down(SimTime::from_secs(99)));
        assert!(plan.imds_down(SimTime::from_secs(100)));
        assert!(plan.imds_down(SimTime::from_secs(219)));
        assert!(!plan.imds_down(SimTime::from_secs(220)));
        assert_eq!(
            plan.outage_ends(SimTime::from_secs(150)),
            Some(SimTime::from_secs(220))
        );
        assert_eq!(plan.outage_ends(SimTime::from_secs(300)), None);
        assert_eq!(
            plan.degraded_poll(SimDuration::from_secs(10)),
            SimDuration::from_secs(60)
        );
        let empty = FaultPlan::none();
        assert!(!empty.imds_down(SimTime::ZERO));
    }

    #[test]
    fn market_knobs_do_not_perturb_storm_and_outage_draws() {
        // shocks draw strictly after storms and outages, so arming
        // [chaos.market] must leave the existing fault stream untouched
        // — the stream-stability contract every chaos knob obeys
        let base = storm_cfg();
        let with_market = ChaosCfg {
            market: crate::config::ChaosMarketCfg {
                shocks: 3,
                factor: 2.5,
                duration: SimDuration::from_mins(20),
            },
            ..base.clone()
        };
        let a = FaultPlan::draw(&base, 7);
        let b = FaultPlan::draw(&with_market, 7);
        assert_eq!(a.storms, b.storms);
        assert_eq!(a.outages, b.outages);
        assert!(a.market_shocks.is_empty());
        assert_eq!(a.market_factor, 1.0);
        assert!(!b.market_shocks.is_empty());
        assert_eq!(b.market_factor, 2.5);
    }

    #[test]
    fn market_shocks_are_merged_ordered_and_off_origin() {
        let cfg = ChaosCfg {
            market: crate::config::ChaosMarketCfg {
                shocks: 8,
                factor: 3.0,
                // long windows on a 100-min draw window force overlaps
                duration: SimDuration::from_mins(45),
            },
            ..storm_cfg()
        };
        let plan = FaultPlan::draw(&cfg, 13);
        assert_eq!(plan, FaultPlan::draw(&cfg, 13), "draw is deterministic");
        assert!(!plan.market_shocks.is_empty());
        assert!(
            plan.market_shocks.len() < 8,
            "8 overlapping 45-min windows in 100 min must merge: {:?}",
            plan.market_shocks
        );
        let mut prev_end = SimDuration::ZERO;
        for &(s, e) in &plan.market_shocks {
            assert!(!s.is_zero(), "shock at t=0 would rewrite the initial epoch");
            assert!(s > prev_end, "windows must be disjoint and ordered");
            assert!(
                e.as_millis() - s.as_millis()
                    >= SimDuration::from_mins(45).as_millis(),
                "a merged window is at least one shock long"
            );
            prev_end = e;
        }
    }

    #[test]
    fn job_zero_matches_single_run_seeds() {
        assert_eq!(storage_seed(42, 9), job_storage_seed(42, 9, 0));
        assert_ne!(job_storage_seed(42, 9, 1), job_storage_seed(42, 9, 2));
        assert_eq!(backoff_seed(42), job_backoff_seed(42, 0));
    }
}
