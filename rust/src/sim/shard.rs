//! Sharded, resumable Monte Carlo sweeps across OS processes.
//!
//! One process tops out well below the million-seed ×
//! thousand-configuration studies the ROADMAP calls for, so this module
//! practices what the simulator preaches: plan the sweep as
//! deterministic shards, checkpoint completed-shard progress, and
//! resume after interruption — the sweep runner checkpoints too.
//!
//! * [`ShardPlan`] deterministically partitions a seed range
//!   ([`SeedStream`], optionally salted so decorrelated streams never
//!   depend on shard boundaries) × a configuration matrix
//!   ([`ConfigVariant`] specs over the interval controllers) into
//!   contiguous shards of cells, filtering invalid combinations (an
//!   adaptive controller without transparent checkpointing) into
//!   `skipped` with reasons. The plan's `fingerprint` covers every
//!   parameter plus the scenario TOML bytes, so a resumed run can never
//!   silently mix artifacts from two different studies.
//! * [`ShardRunner`] orchestrates: it spawns up to P worker processes
//!   (`spoton sweep-worker --dir … --shard k`, re-invoking the same
//!   binary), verifies each finished shard's artifact, checkpoints a
//!   completed-shard manifest after every completion, retries failures
//!   a bounded number of times, and dead-letters shards that keep
//!   failing — with their full `(config, seed)` cell list for replay.
//!   Re-running the same plan over an existing run directory resumes:
//!   only missing (or corrupt) shards re-run.
//! * [`run_shard`] is the worker body: it runs the shard's cells through
//!   the same atomic-work-index thread pool idiom as
//!   [`super::sweep::Sweep::run`], one engine per cell, and returns a
//!   [`ShardArtifact`] the worker writes with
//!   [`crate::util::atomic_write`] — rename-atomic, so a killed worker
//!   leaves no observable partial artifact (a torn write that somehow
//!   lands anyway is rejected at merge time by parse + fingerprint +
//!   cell validation).
//! * [`merge`] folds artifacts **by shard id** into a [`MergedSweep`]:
//!   per-cell digest hashes concatenated in global cell order and
//!   hashed again ([`fold_cell_shas`]) plus per-variant distribution
//!   summaries. Cell order is a pure function of the plan, so the
//!   merged digest is byte-identical at any process count, any thread
//!   count, any shard count, and across interrupt-and-resume — exactly
//!   the invariant `tests/sweep_determinism.rs` pins for in-process
//!   sweeps, extended to the multi-process runner
//!   ([`fold_run_digests`] folds an in-process sweep's digests into the
//!   same value for direct comparison).
//!
//! ## Run-directory layout
//!
//! ```text
//! shards/<run_id>/
//!   scenario.toml        # the scenario, byte-for-byte (sha pinned in PLAN)
//!   PLAN.json            # the ShardPlan (+ scenario_base for trace paths)
//!   MANIFEST.json        # checkpointed progress: completed shards + DLQ
//!   shard-<k>.json       # one validated artifact per completed shard
//!   shard-<k>.stderr.log # the worker's stderr, kept per attempt
//!   MERGED.json          # digest + per-variant summaries, once complete
//! ```
//!
//! All JSON is written with sorted keys (objects are `BTreeMap`s) and
//! `u64` values that may exceed 2^53 (seeds, salts) are serialized as
//! decimal strings, so every artifact is stably diffable and
//! round-trips exactly.

use super::cluster::{cluster_digest, ClusterResult};
use super::experiment::Experiment;
use super::sweep::run_digest;
use super::RunResult;
use crate::config::{CheckpointMethodCfg, IntervalControllerCfg, ScenarioConfig};
use crate::json::{self, Value};
use crate::metrics::RecordLevel;
use crate::report::distribution::{Summarizer, Summary};
use crate::util::{atomic_write, prng::mix64, sha256_hex};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

pub const PLAN_FORMAT: &str = "spoton-shard-plan/1";
pub const ARTIFACT_FORMAT: &str = "spoton-shard-artifact/1";
pub const MANIFEST_FORMAT: &str = "spoton-shard-manifest/1";
pub const MERGE_FORMAT: &str = "spoton-shard-merge/1";

/// Serialize a u64 losslessly (JSON numbers are f64 — salted seeds use
/// all 64 bits).
fn u64_str(v: u64) -> Value {
    Value::Str(v.to_string())
}

fn req_u64_str(v: &Value, key: &str) -> Result<u64> {
    v.req_str(key)?
        .parse::<u64>()
        .with_context(|| format!("field '{key}' is not a u64"))
}

/// A deterministic seed stream: `count` seeds addressed by global index.
///
/// `salt == 0` yields the contiguous range `start..start+count` (the
/// same seeds `Sweep::seed_range` produces, so sharded output is
/// directly comparable to pinned in-process sweeps). A non-zero salt
/// derives each seed as `mix64(salt ^ mix64(start + j))` — decorrelated
/// across `j` and across salts, and a function of the *global* index
/// only, so re-planning with a different shard count yields the
/// byte-identical merged output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SeedStream {
    pub start: u64,
    pub count: usize,
    pub salt: u64,
}

impl SeedStream {
    /// The contiguous range `start .. start + count` (salt 0).
    pub fn contiguous(start: u64, count: usize) -> Self {
        Self { start, count, salt: 0 }
    }

    /// A salted, decorrelated stream of `count` seeds.
    pub fn salted(start: u64, count: usize, salt: u64) -> Self {
        Self { start, count, salt }
    }

    /// The seed at global index `j` (must be `< count`).
    pub fn seed(&self, j: usize) -> u64 {
        debug_assert!(j < self.count);
        let base = self.start.wrapping_add(j as u64);
        if self.salt == 0 {
            base
        } else {
            mix64(self.salt ^ mix64(base))
        }
    }

    /// Every seed, in index order.
    pub fn iter(&self) -> impl Iterator<Item = u64> + '_ {
        (0..self.count).map(|j| self.seed(j))
    }
}

/// One configuration-matrix axis value: a parsed variant spec. Specs are
/// strings so plans round-trip through JSON and the CLI verbatim:
///
/// * `base` — the scenario exactly as configured;
/// * `fixed` — force the fixed-interval controller;
/// * `young-daly` / `young-daly-ho` — the Young/Daly controller
///   (first-order / Daly's higher-order correction);
/// * `cost-aware` / `cost-aware:<sensitivity>` — price-scaled
///   Young/Daly.
#[derive(Debug, Clone, PartialEq)]
pub struct ConfigVariant {
    pub spec: String,
    controller: Option<IntervalControllerCfg>,
}

impl ConfigVariant {
    /// The scenario as-is (no controller override).
    pub fn base() -> Self {
        Self { spec: "base".into(), controller: None }
    }

    pub fn parse(spec: &str) -> Result<Self> {
        let controller = match spec {
            "base" => None,
            "fixed" => Some(IntervalControllerCfg::Fixed),
            "young-daly" => Some(IntervalControllerCfg::young_daly()),
            "young-daly-ho" => {
                let mut c = IntervalControllerCfg::young_daly();
                if let IntervalControllerCfg::YoungDaly {
                    higher_order, ..
                } = &mut c
                {
                    *higher_order = true;
                }
                Some(c)
            }
            "cost-aware" => Some(IntervalControllerCfg::cost_aware(1.0)),
            other => match other.strip_prefix("cost-aware:") {
                Some(s) => {
                    let sensitivity: f64 = s.parse().with_context(|| {
                        format!("bad cost-aware sensitivity '{s}'")
                    })?;
                    if !sensitivity.is_finite() || sensitivity <= 0.0 {
                        bail!(
                            "cost-aware sensitivity must be finite and > 0, \
                             got {sensitivity}"
                        );
                    }
                    Some(IntervalControllerCfg::cost_aware(sensitivity))
                }
                None => bail!(
                    "unknown config variant '{other}' (expected base, fixed, \
                     young-daly, young-daly-ho, cost-aware[:S])"
                ),
            },
        };
        Ok(Self { spec: spec.to_string(), controller })
    }

    /// Apply the variant to a scenario (controller override only; `base`
    /// is the identity).
    pub fn apply(&self, cfg: &mut ScenarioConfig) {
        if let Some(c) = &self.controller {
            cfg.adaptive = c.clone();
        }
    }

    /// Why this variant cannot run on `cfg`, if it can't — the
    /// invalid-combination filter. Adaptive interval controllers tune
    /// the transparent checkpoint cadence, so they require
    /// `checkpoint.method = "transparent"` (the same rule the
    /// `[checkpoint.adaptive]` TOML section enforces).
    pub fn invalid_reason(&self, cfg: &ScenarioConfig) -> Option<String> {
        match &self.controller {
            Some(c)
                if *c != IntervalControllerCfg::Fixed
                    && !matches!(
                        cfg.checkpoint,
                        CheckpointMethodCfg::Transparent { .. }
                    ) =>
            {
                Some(format!(
                    "adaptive controller '{}' requires transparent \
                     checkpointing (checkpoint.method = \"{}\")",
                    self.spec,
                    cfg.checkpoint.label()
                ))
            }
            _ => None,
        }
    }
}

/// A combination the planner filtered out, with the reason.
#[derive(Debug, Clone, PartialEq)]
pub struct SkippedVariant {
    pub spec: String,
    pub reason: String,
}

/// The deterministic partition of seed range × configuration matrix
/// into shards. Cells are numbered config-major: cell
/// `m = config_idx * seed_count + seed_idx`; shard `k` owns a
/// contiguous, balanced range of cells. Everything here is a pure
/// function of the constructor inputs — two processes that parse the
/// same `PLAN.json` agree on every cell of every shard.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    pub run_id: String,
    pub seeds: SeedStream,
    /// Valid variants, in requested order (the config axis).
    pub configs: Vec<ConfigVariant>,
    /// Filtered-out combinations, with reasons.
    pub skipped: Vec<SkippedVariant>,
    pub shards: usize,
    /// sha256 of the scenario TOML text this plan was built against.
    pub scenario_sha: String,
    /// The originally-requested spec list (including later-skipped
    /// entries) — what `fingerprint` covers and JSON round-trips.
    requested: Vec<String>,
    fingerprint: String,
}

impl ShardPlan {
    /// Plan a sweep. `specs` empty means a single `base` variant.
    /// `shards` is clamped into `1..=cells`.
    pub fn new(
        run_id: &str,
        seeds: SeedStream,
        specs: &[String],
        scenario: &ScenarioConfig,
        scenario_text: &str,
        shards: usize,
    ) -> Result<Self> {
        if run_id.is_empty()
            || !run_id.chars().all(|c| {
                c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.')
            })
        {
            bail!(
                "run id '{run_id}' must be non-empty [A-Za-z0-9._-] \
                 (it names a directory)"
            );
        }
        if seeds.count == 0 {
            bail!("a sweep needs at least one seed");
        }
        let requested: Vec<String> = if specs.is_empty() {
            vec!["base".to_string()]
        } else {
            specs.to_vec()
        };
        let mut configs: Vec<ConfigVariant> = Vec::new();
        let mut skipped = Vec::new();
        for spec in &requested {
            let v = ConfigVariant::parse(spec)?;
            if configs.iter().any(|c| c.spec == v.spec) {
                bail!("duplicate config variant '{}'", v.spec);
            }
            match v.invalid_reason(scenario) {
                Some(reason) => {
                    skipped.push(SkippedVariant { spec: v.spec, reason })
                }
                None => configs.push(v),
            }
        }
        if configs.is_empty() {
            bail!(
                "every requested configuration was filtered out: {}",
                skipped
                    .iter()
                    .map(|s| format!("{} ({})", s.spec, s.reason))
                    .collect::<Vec<_>>()
                    .join("; ")
            );
        }
        let cells = configs.len() * seeds.count;
        let shards = shards.clamp(1, cells);
        let scenario_sha = sha256_hex(scenario_text.as_bytes());
        // NOTE: run_id is deliberately outside the fingerprint — it
        // names the run directory; the fingerprint identifies the work.
        let canon = format!(
            "{PLAN_FORMAT}|start={}|count={}|salt={}|shards={shards}|\
             configs={}|scenario={scenario_sha}",
            seeds.start,
            seeds.count,
            seeds.salt,
            requested.join(",")
        );
        let fingerprint = sha256_hex(canon.as_bytes());
        Ok(Self {
            run_id: run_id.to_string(),
            seeds,
            configs,
            skipped,
            shards,
            scenario_sha,
            requested,
            fingerprint,
        })
    }

    /// Identifies the planned work (parameters + scenario bytes), not
    /// the directory it runs in. Artifacts and manifests carry it, so a
    /// resume against an edited scenario or changed parameters is
    /// rejected instead of silently mixing incompatible results.
    pub fn fingerprint(&self) -> &str {
        &self.fingerprint
    }

    /// Total cells (valid configs × seeds).
    pub fn cells(&self) -> usize {
        self.configs.len() * self.seeds.count
    }

    /// The contiguous cell range shard `k` owns. Balanced split: every
    /// shard gets `cells/shards` cells, the first `cells%shards` shards
    /// one extra — never an empty shard.
    pub fn shard_range(&self, shard: usize) -> Range<usize> {
        assert!(shard < self.shards, "shard {shard} of {}", self.shards);
        let m = self.cells();
        let base = m / self.shards;
        let rem = m % self.shards;
        let lo = shard * base + shard.min(rem);
        let len = base + usize::from(shard < rem);
        lo..lo + len
    }

    /// Resolve cell `m` to `(config index, seed)`.
    pub fn cell(&self, m: usize) -> (usize, u64) {
        let n = self.seeds.count;
        (m / n, self.seeds.seed(m % n))
    }

    /// The plan as JSON (`PLAN.json` body; sorted keys, u64s as
    /// strings).
    pub fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("format", PLAN_FORMAT)
            .set("run_id", self.run_id.as_str())
            .set("seed_start", u64_str(self.seeds.start))
            .set("seed_count", self.seeds.count)
            .set("salt", u64_str(self.seeds.salt))
            .set("shards", self.shards)
            .set("cells", self.cells())
            .set(
                "configs",
                self.requested
                    .iter()
                    .map(|s| Value::Str(s.clone()))
                    .collect::<Vec<_>>(),
            )
            .set(
                "resolved",
                self.configs
                    .iter()
                    .map(|c| Value::Str(c.spec.clone()))
                    .collect::<Vec<_>>(),
            )
            .set(
                "skipped",
                self.skipped
                    .iter()
                    .map(|s| {
                        let mut o = Value::obj();
                        o.set("spec", s.spec.as_str())
                            .set("reason", s.reason.as_str());
                        o
                    })
                    .collect::<Vec<_>>(),
            )
            .set("scenario_sha256", self.scenario_sha.as_str())
            .set("fingerprint", self.fingerprint.as_str());
        v
    }

    /// Rebuild a plan from `PLAN.json` + the scenario it references.
    /// Re-plans from the stored parameters and verifies the stored
    /// fingerprint matches — drift (edited scenario text, edited plan
    /// fields) is an error, not a silent divergence.
    pub fn from_json(
        v: &Value,
        scenario: &ScenarioConfig,
        scenario_text: &str,
    ) -> Result<Self> {
        let format = v.req_str("format")?;
        if format != PLAN_FORMAT {
            bail!("unsupported plan format '{format}'");
        }
        let seeds = SeedStream {
            start: req_u64_str(v, "seed_start")?,
            count: v.req_u64("seed_count")? as usize,
            salt: req_u64_str(v, "salt")?,
        };
        let specs: Vec<String> = v
            .req_array("configs")?
            .iter()
            .map(|s| {
                s.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("non-string config spec"))
            })
            .collect::<Result<_>>()?;
        let plan = Self::new(
            v.req_str("run_id")?,
            seeds,
            &specs,
            scenario,
            scenario_text,
            v.req_u64("shards")? as usize,
        )?;
        let stored = v.req_str("fingerprint")?;
        if plan.fingerprint != stored {
            bail!(
                "plan fingerprint mismatch: stored {stored}, recomputed {} \
                 (scenario or plan edited since planning?)",
                plan.fingerprint
            );
        }
        Ok(plan)
    }
}

/// The per-cell metrics an artifact carries for merged summaries (all
/// f64 — Rust's shortest-round-trip float formatting means they survive
/// the JSON round trip bit-exactly).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CellMetrics {
    pub makespan_secs: f64,
    pub total_cost: f64,
    pub evictions: f64,
    pub restores: f64,
    pub lost_steps: f64,
    pub completed: bool,
}

impl CellMetrics {
    fn from_run(r: &RunResult) -> Self {
        Self {
            makespan_secs: r.total.as_secs_f64(),
            total_cost: r.total_cost(),
            evictions: r.evictions as f64,
            restores: r.restores as f64,
            lost_steps: r.lost_steps as f64,
            completed: r.completed,
        }
    }

    fn from_cluster(r: &ClusterResult) -> Self {
        let sum = |f: &dyn Fn(&RunResult) -> f64| -> f64 {
            r.jobs.iter().map(|j| f(&j.result)).sum()
        };
        Self {
            makespan_secs: r.makespan.as_secs_f64(),
            total_cost: r.total_cost(),
            evictions: sum(&|j| j.evictions as f64),
            restores: sum(&|j| j.restores as f64),
            lost_steps: sum(&|j| j.lost_steps as f64),
            completed: r.completed_jobs() == r.jobs.len(),
        }
    }
}

/// One executed cell: its identity under the plan, the sha256 of its
/// full canonical digest ([`run_digest`] / [`cluster_digest`]), and the
/// metrics the merger summarizes.
#[derive(Debug, Clone, PartialEq)]
pub struct CellRecord {
    pub cell: usize,
    /// The variant spec that ran (redundant with `cell`; validated).
    pub config: String,
    pub seed: u64,
    /// sha256 (hex) of the cell's canonical digest string.
    pub digest_sha: String,
    pub metrics: CellMetrics,
}

impl CellRecord {
    fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("cell", self.cell)
            .set("config", self.config.as_str())
            .set("seed", u64_str(self.seed))
            .set("digest_sha256", self.digest_sha.as_str())
            .set("makespan_secs", self.metrics.makespan_secs)
            .set("total_cost", self.metrics.total_cost)
            .set("evictions", self.metrics.evictions)
            .set("restores", self.metrics.restores)
            .set("lost_steps", self.metrics.lost_steps)
            .set("completed", self.metrics.completed);
        v
    }

    fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            cell: v.req_u64("cell")? as usize,
            config: v.req_str("config")?.to_string(),
            seed: req_u64_str(v, "seed")?,
            digest_sha: v.req_str("digest_sha256")?.to_string(),
            metrics: CellMetrics {
                makespan_secs: v.req_f64("makespan_secs")?,
                total_cost: v.req_f64("total_cost")?,
                evictions: v.req_f64("evictions")?,
                restores: v.req_f64("restores")?,
                lost_steps: v.req_f64("lost_steps")?,
                completed: v
                    .get("completed")
                    .and_then(Value::as_bool)
                    .context("missing bool field 'completed'")?,
            },
        })
    }
}

/// One worker's output: every cell of one shard, plus bench counters
/// (wall-clock is observability only — it never enters a digest or a
/// summary, so artifacts stay comparable across machines).
#[derive(Debug, Clone)]
pub struct ShardArtifact {
    pub run_id: String,
    pub shard: usize,
    pub fingerprint: String,
    pub cells: Vec<CellRecord>,
    /// Worker wall-clock for the shard, in milliseconds.
    pub wall_ms: u64,
}

impl ShardArtifact {
    pub fn to_json(&self) -> Value {
        let runs_per_sec = if self.wall_ms == 0 {
            0.0
        } else {
            self.cells.len() as f64 / (self.wall_ms as f64 / 1000.0)
        };
        let mut v = Value::obj();
        v.set("format", ARTIFACT_FORMAT)
            .set("run_id", self.run_id.as_str())
            .set("shard", self.shard)
            .set("fingerprint", self.fingerprint.as_str())
            .set(
                "cells",
                self.cells.iter().map(CellRecord::to_json).collect::<Vec<_>>(),
            )
            .set("wall_ms", self.wall_ms)
            .set("runs_per_sec", runs_per_sec);
        v
    }

    pub fn from_json(v: &Value) -> Result<Self> {
        let format = v.req_str("format")?;
        if format != ARTIFACT_FORMAT {
            bail!("unsupported artifact format '{format}'");
        }
        Ok(Self {
            run_id: v.req_str("run_id")?.to_string(),
            shard: v.req_u64("shard")? as usize,
            fingerprint: v.req_str("fingerprint")?.to_string(),
            cells: v
                .req_array("cells")?
                .iter()
                .map(CellRecord::from_json)
                .collect::<Result<_>>()?,
            wall_ms: v.req_u64("wall_ms")?,
        })
    }

    /// Full validation against the plan: identity, fingerprint, and
    /// every cell's (index, config, seed) exactly as planned — a
    /// partial or tampered artifact cannot pass.
    pub fn validate(&self, plan: &ShardPlan, shard: usize) -> Result<()> {
        if self.run_id != plan.run_id {
            bail!("artifact run id '{}' != '{}'", self.run_id, plan.run_id);
        }
        if self.shard != shard {
            bail!("artifact is for shard {}, expected {shard}", self.shard);
        }
        if self.fingerprint != plan.fingerprint {
            bail!(
                "artifact fingerprint mismatch (plan or scenario changed \
                 since this shard ran)"
            );
        }
        let range = plan.shard_range(shard);
        if self.cells.len() != range.len() {
            bail!(
                "shard {shard} artifact has {} cells, plan says {}",
                self.cells.len(),
                range.len()
            );
        }
        for (rec, m) in self.cells.iter().zip(range) {
            let (ci, seed) = plan.cell(m);
            if rec.cell != m {
                bail!("cell index {} out of order (expected {m})", rec.cell);
            }
            if rec.config != plan.configs[ci].spec {
                bail!(
                    "cell {m} ran config '{}', plan says '{}'",
                    rec.config,
                    plan.configs[ci].spec
                );
            }
            if rec.seed != seed {
                bail!("cell {m} ran seed {}, plan says {seed}", rec.seed);
            }
            if rec.digest_sha.len() != 64
                || !rec.digest_sha.chars().all(|c| c.is_ascii_hexdigit())
            {
                bail!("cell {m} digest '{}' is not sha256 hex", rec.digest_sha);
            }
        }
        Ok(())
    }
}

/// Artifact path for one shard.
pub fn artifact_path(dir: &Path, shard: usize) -> PathBuf {
    dir.join(format!("shard-{shard}.json"))
}

fn run_cell(
    plan: &ShardPlan,
    scenario: &ScenarioConfig,
    m: usize,
) -> Result<CellRecord> {
    let (ci, seed) = plan.cell(m);
    let variant = &plan.configs[ci];
    let mut cfg = scenario.clone();
    variant.apply(&mut cfg);
    cfg.seed = seed;
    // same lean metrics level Sweep::run uses — per-kind counters still
    // enter the digest, so the byte-identity contract is unchanged
    cfg.metrics = RecordLevel::Counts;
    let exp = Experiment { cfg };
    let (digest, metrics) = if exp.cfg.cluster.is_some() {
        let r = exp.run_cluster_sleeper()?;
        (cluster_digest(&r), CellMetrics::from_cluster(&r))
    } else {
        let r = exp.run_sleeper()?;
        (run_digest(&r), CellMetrics::from_run(&r))
    };
    Ok(CellRecord {
        cell: m,
        config: variant.spec.clone(),
        seed,
        digest_sha: sha256_hex(digest.as_bytes()),
        metrics,
    })
}

/// Execute one shard in-process: the worker body behind
/// `spoton sweep-worker`. Cells run on an atomic-work-index thread pool
/// (the [`super::sweep::Sweep::run`] idiom — no shared mutable state,
/// results merged by cell position), so worker thread count is as
/// invisible in the artifact as process count is in the merge.
pub fn run_shard(
    plan: &ShardPlan,
    scenario: &ScenarioConfig,
    shard: usize,
    threads: usize,
) -> Result<ShardArtifact> {
    let cells: Vec<usize> = plan.shard_range(shard).collect();
    let n = cells.len();
    let workers = threads.clamp(1, n.max(1));
    let t0 = Instant::now();
    let mut slots: Vec<Option<Result<CellRecord>>> =
        (0..n).map(|_| None).collect();
    if workers <= 1 {
        for (i, &m) in cells.iter().enumerate() {
            slots[i] = Some(run_cell(plan, scenario, m));
        }
    } else {
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            let mut handles = Vec::with_capacity(workers);
            for _ in 0..workers {
                let next = &next;
                let cells = &cells;
                handles.push(scope.spawn(move || {
                    let mut local: Vec<(usize, Result<CellRecord>)> =
                        Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, run_cell(plan, scenario, cells[i])));
                    }
                    local
                }));
            }
            for h in handles {
                // spoton-lint: allow(D3, reason = "a panicked worker is a bug; re-raise it")
                for (i, r) in h.join().expect("shard worker panicked") {
                    slots[i] = Some(r);
                }
            }
        });
    }
    let records: Vec<CellRecord> = slots
        .into_iter()
        // spoton-lint: allow(D3, reason = "the plan visits every index exactly once")
        .map(|slot| slot.expect("every cell index visited exactly once"))
        .collect::<Result<_>>()?;
    Ok(ShardArtifact {
        run_id: plan.run_id.clone(),
        shard,
        fingerprint: plan.fingerprint.clone(),
        cells: records,
        wall_ms: t0.elapsed().as_millis() as u64,
    })
}

/// Read + parse + validate one shard artifact; returns it with the
/// sha256 of its file bytes (what the manifest records).
pub fn verify_artifact(
    dir: &Path,
    plan: &ShardPlan,
    shard: usize,
) -> Result<(ShardArtifact, String)> {
    let path = artifact_path(dir, shard);
    let bytes = std::fs::read(&path)
        .with_context(|| format!("reading {}", path.display()))?;
    let text = std::str::from_utf8(&bytes)
        .with_context(|| format!("{} is not UTF-8", path.display()))?;
    let v = json::parse(text)
        .map_err(|e| anyhow!("{}: {e}", path.display()))?;
    let art = ShardArtifact::from_json(&v)
        .with_context(|| format!("parsing {}", path.display()))?;
    art.validate(plan, shard)
        .with_context(|| format!("validating {}", path.display()))?;
    Ok((art, sha256_hex(&bytes)))
}

/// Fold per-cell digest hashes (in global cell order) into the merged
/// sweep digest: newline-joined, sha256'd.
pub fn fold_cell_shas<I, S>(shas: I) -> String
where
    I: IntoIterator<Item = S>,
    S: AsRef<str>,
{
    let mut buf = String::new();
    for sha in shas {
        buf.push_str(sha.as_ref());
        buf.push('\n');
    }
    sha256_hex(buf.as_bytes())
}

/// Fold full per-run digest strings ([`run_digest`] / [`cluster_digest`]
/// output, in cell order) into the merged digest: sha256 each, then
/// [`fold_cell_shas`]. An in-process `Sweep::run` folded this way must
/// equal a sharded run's [`MergedSweep::digest`] — the cross-process
/// equality `tests/sweep_determinism.rs` pins.
pub fn fold_run_digests<I, S>(digests: I) -> String
where
    I: IntoIterator<Item = S>,
    S: AsRef<str>,
{
    fold_cell_shas(
        digests.into_iter().map(|d| sha256_hex(d.as_ref().as_bytes())),
    )
}

/// One variant's merged population, reduced.
#[derive(Debug, Clone)]
pub struct VariantSummary {
    pub spec: String,
    pub runs: usize,
    pub completed: usize,
    pub makespan_secs: Summary,
    pub total_cost: Summary,
    pub evictions: Summary,
    pub restores: Summary,
    pub lost_steps: Summary,
}

impl VariantSummary {
    fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("config", self.spec.as_str())
            .set("runs", self.runs)
            .set("completed", self.completed)
            .set("makespan_secs", self.makespan_secs.to_json())
            .set("total_cost", self.total_cost.to_json())
            .set("evictions", self.evictions.to_json())
            .set("restores", self.restores.to_json())
            .set("lost_steps", self.lost_steps.to_json());
        v
    }
}

/// The merged sweep: every cell in global order, the fold digest, and
/// per-variant summaries.
#[derive(Debug, Clone)]
pub struct MergedSweep {
    pub digest: String,
    pub cells: Vec<CellRecord>,
    pub summaries: Vec<VariantSummary>,
}

impl MergedSweep {
    /// Deterministic `MERGED.json` body: digest + summaries (cell
    /// records stay in the per-shard artifacts — at a million seeds the
    /// merge file must not re-carry them all).
    pub fn to_json(&self, plan: &ShardPlan) -> Value {
        let mut v = Value::obj();
        v.set("format", MERGE_FORMAT)
            .set("run_id", plan.run_id.as_str())
            .set("fingerprint", plan.fingerprint())
            .set("digest", self.digest.as_str())
            .set("cells", self.cells.len())
            .set("shards", plan.shards)
            .set(
                "skipped",
                plan.skipped
                    .iter()
                    .map(|s| {
                        let mut o = Value::obj();
                        o.set("spec", s.spec.as_str())
                            .set("reason", s.reason.as_str());
                        o
                    })
                    .collect::<Vec<_>>(),
            )
            .set(
                "summaries",
                self.summaries
                    .iter()
                    .map(VariantSummary::to_json)
                    .collect::<Vec<_>>(),
            );
        v
    }

    /// Human-readable per-variant table.
    pub fn render(&self) -> String {
        use crate::report::table::TextTable;
        use crate::util::fmt::{dollars, hms_f64 as hms};
        let mut t = TextTable::new(&[
            "Config",
            "Runs",
            "Completed",
            "Makespan p50",
            "Makespan p95",
            "Cost mean",
            "Cost p95",
            "Evictions mean",
        ]);
        for s in &self.summaries {
            t.row(&[
                s.spec.clone(),
                s.runs.to_string(),
                s.completed.to_string(),
                hms(s.makespan_secs.p50),
                hms(s.makespan_secs.p95),
                dollars(s.total_cost.mean),
                dollars(s.total_cost.p95),
                format!("{:.2}", s.evictions.mean),
            ]);
        }
        t.render()
    }
}

/// Merge a complete run directory **by shard id**: artifacts are read
/// in shard order (shards own contiguous ascending cell ranges, so
/// concatenation is global cell order), validated against the plan, and
/// folded into the digest + per-variant summaries. Any missing, torn,
/// or mismatched artifact fails the merge — it never guesses.
pub fn merge(dir: &Path, plan: &ShardPlan) -> Result<MergedSweep> {
    let mut cells: Vec<CellRecord> = Vec::with_capacity(plan.cells());
    for shard in 0..plan.shards {
        let (art, _) = verify_artifact(dir, plan, shard)
            .with_context(|| format!("merging shard {shard}"))?;
        cells.extend(art.cells);
    }
    let digest = fold_cell_shas(cells.iter().map(|c| c.digest_sha.as_str()));
    // per-variant summaries through one reused Summarizer (cells are
    // config-major: variant v owns cells[v*n .. (v+1)*n])
    let n = plan.seeds.count;
    let mut sz = Summarizer::new();
    let summaries = plan
        .configs
        .iter()
        .enumerate()
        .map(|(v, cfg)| {
            let slice = &cells[v * n..(v + 1) * n];
            let mut metric = |f: &dyn Fn(&CellMetrics) -> f64| -> Summary {
                for rec in slice {
                    sz.push(f(&rec.metrics));
                }
                sz.finish()
            };
            VariantSummary {
                spec: cfg.spec.clone(),
                runs: slice.len(),
                completed: slice
                    .iter()
                    .filter(|r| r.metrics.completed)
                    .count(),
                makespan_secs: metric(&|m| m.makespan_secs),
                total_cost: metric(&|m| m.total_cost),
                evictions: metric(&|m| m.evictions),
                restores: metric(&|m| m.restores),
                lost_steps: metric(&|m| m.lost_steps),
            }
        })
        .collect();
    Ok(MergedSweep { digest, cells, summaries })
}

/// A shard that exhausted its retries, with everything needed to replay
/// it: the attempt count, the last failure reason, and the full
/// `(config, seed)` cell list.
#[derive(Debug, Clone)]
pub struct DeadLetter {
    pub shard: usize,
    pub attempts: u32,
    pub reason: String,
    pub cells: Vec<(String, u64)>,
}

impl DeadLetter {
    fn for_shard(
        plan: &ShardPlan,
        shard: usize,
        attempts: u32,
        reason: String,
    ) -> Self {
        let cells = plan
            .shard_range(shard)
            .map(|m| {
                let (ci, seed) = plan.cell(m);
                (plan.configs[ci].spec.clone(), seed)
            })
            .collect();
        Self { shard, attempts, reason, cells }
    }

    fn to_json(&self) -> Value {
        let mut v = Value::obj();
        v.set("shard", self.shard)
            .set("attempts", u64::from(self.attempts))
            .set("reason", self.reason.as_str())
            .set(
                "cells",
                self.cells
                    .iter()
                    .map(|(config, seed)| {
                        let mut o = Value::obj();
                        o.set("config", config.as_str())
                            .set("seed", u64_str(*seed));
                        o
                    })
                    .collect::<Vec<_>>(),
            );
        v
    }

    fn from_json(v: &Value) -> Result<Self> {
        Ok(Self {
            shard: v.req_u64("shard")? as usize,
            attempts: u32::try_from(v.req_u64("attempts")?)
                .context("dead-letter 'attempts' out of u32 range")?,
            reason: v.req_str("reason")?.to_string(),
            cells: v
                .req_array("cells")?
                .iter()
                .map(|c| {
                    Ok((
                        c.req_str("config")?.to_string(),
                        req_u64_str(c, "seed")?,
                    ))
                })
                .collect::<Result<_>>()?,
        })
    }
}

/// The checkpointed progress record (`MANIFEST.json`): which shards
/// completed (with their artifact file hashes) and which dead-lettered.
/// Rewritten atomically after every state change — an orchestrator
/// killed at any instant leaves a manifest a resume can trust.
#[derive(Debug, Clone, Default)]
struct Manifest {
    run_id: String,
    fingerprint: String,
    completed: BTreeMap<usize, String>,
    dead_letter: Vec<DeadLetter>,
}

impl Manifest {
    fn path(dir: &Path) -> PathBuf {
        dir.join("MANIFEST.json")
    }

    fn fresh(plan: &ShardPlan) -> Self {
        Self {
            run_id: plan.run_id.clone(),
            fingerprint: plan.fingerprint.clone(),
            ..Self::default()
        }
    }

    fn load_or_new(dir: &Path, plan: &ShardPlan) -> Result<Self> {
        let path = Self::path(dir);
        if !path.exists() {
            return Ok(Self::fresh(plan));
        }
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = json::parse(&text)
            .map_err(|e| anyhow!("{}: {e}", path.display()))?;
        let format = v.req_str("format")?;
        if format != MANIFEST_FORMAT {
            bail!("unsupported manifest format '{format}'");
        }
        let m = Self {
            run_id: v.req_str("run_id")?.to_string(),
            fingerprint: v.req_str("fingerprint")?.to_string(),
            completed: v
                .req_array("completed")?
                .iter()
                .map(|e| {
                    Ok((
                        e.req_u64("shard")? as usize,
                        e.req_str("artifact_sha256")?.to_string(),
                    ))
                })
                .collect::<Result<_>>()?,
            dead_letter: v
                .req_array("dead_letter")?
                .iter()
                .map(DeadLetter::from_json)
                .collect::<Result<_>>()?,
        };
        if m.run_id != plan.run_id || m.fingerprint != plan.fingerprint {
            bail!(
                "manifest in {} belongs to a different run/plan — refusing \
                 to resume over it",
                dir.display()
            );
        }
        Ok(m)
    }

    fn save(&self, dir: &Path) -> Result<()> {
        let mut v = Value::obj();
        v.set("format", MANIFEST_FORMAT)
            .set("run_id", self.run_id.as_str())
            .set("fingerprint", self.fingerprint.as_str())
            .set(
                "completed",
                self.completed
                    .iter()
                    .map(|(shard, sha)| {
                        let mut o = Value::obj();
                        o.set("shard", *shard)
                            .set("artifact_sha256", sha.as_str());
                        o
                    })
                    .collect::<Vec<_>>(),
            )
            .set(
                "dead_letter",
                self.dead_letter
                    .iter()
                    .map(DeadLetter::to_json)
                    .collect::<Vec<_>>(),
            );
        let mut body = json::to_string_pretty(&v);
        body.push('\n');
        atomic_write(&Self::path(dir), body.as_bytes())
            .context("writing MANIFEST.json")
    }
}

/// What one `ShardRunner::run` invocation produced.
#[derive(Debug)]
pub struct ShardedOutcome {
    /// The merged sweep — present iff every shard completed and
    /// validated.
    pub merged: Option<MergedSweep>,
    /// Shards that exhausted retries this invocation.
    pub dead_letter: Vec<DeadLetter>,
    /// Shards freshly executed by this invocation.
    pub ran: Vec<usize>,
    /// Shards reused from the checkpointed manifest.
    pub reused: Vec<usize>,
}

/// The multi-process orchestrator: spawns worker processes over a run
/// directory, checkpoints progress, retries, dead-letters, and merges.
#[derive(Debug, Clone)]
pub struct ShardRunner {
    plan: ShardPlan,
    dir: PathBuf,
    exe: PathBuf,
    procs: usize,
    threads: usize,
    retries: u32,
    envs: Vec<(String, String)>,
    scenario_base: Option<PathBuf>,
}

impl ShardRunner {
    /// `exe` is the `spoton` binary to re-invoke (`current_exe()` from
    /// the CLI, `env!("CARGO_BIN_EXE_spoton")` from tests/benches).
    pub fn new(plan: ShardPlan, dir: impl Into<PathBuf>, exe: impl Into<PathBuf>) -> Self {
        Self {
            plan,
            dir: dir.into(),
            exe: exe.into(),
            procs: 1,
            threads: 1,
            retries: 2,
            envs: Vec::new(),
            scenario_base: None,
        }
    }

    /// Max concurrent worker processes (default 1).
    pub fn procs(mut self, n: usize) -> Self {
        self.procs = n.max(1);
        self
    }

    /// Threads per worker process (default 1).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    /// Extra attempts after a shard's first failure (default 2).
    pub fn retries(mut self, n: u32) -> Self {
        self.retries = n;
        self
    }

    /// Extra environment for spawned workers (tests use this to inject
    /// failures; see `spoton sweep-worker`'s `SPOTON_TEST_*` hooks).
    pub fn env(mut self, key: &str, value: &str) -> Self {
        self.envs.push((key.to_string(), value.to_string()));
        self
    }

    /// Directory relative `price_trace` paths in the scenario resolve
    /// against (recorded in `PLAN.json` for workers; defaults to the
    /// run directory).
    pub fn scenario_base(mut self, base: Option<PathBuf>) -> Self {
        self.scenario_base = base;
        self
    }

    pub fn plan(&self) -> &ShardPlan {
        &self.plan
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Create (or verify) the run directory: `scenario.toml` +
    /// `PLAN.json`. Idempotent; an existing directory must carry the
    /// same plan fingerprint or this bails — resuming a *different*
    /// study over old artifacts is always an error.
    pub fn init(&self, scenario_text: &str) -> Result<()> {
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating {}", self.dir.display()))?;
        let plan_path = self.dir.join("PLAN.json");
        if plan_path.exists() {
            let (existing, _) = load_run_dir(&self.dir)?;
            if existing.fingerprint != self.plan.fingerprint {
                bail!(
                    "{} holds a different plan (fingerprint {} != {}); use a \
                     fresh --run-id or directory",
                    self.dir.display(),
                    existing.fingerprint,
                    self.plan.fingerprint
                );
            }
            return Ok(());
        }
        atomic_write(
            &self.dir.join("scenario.toml"),
            scenario_text.as_bytes(),
        )
        .context("writing scenario.toml")?;
        let mut plan_json = self.plan.to_json();
        if let Some(base) = &self.scenario_base {
            plan_json
                .set("scenario_base", base.to_string_lossy().into_owned());
        }
        let mut body = json::to_string_pretty(&plan_json);
        body.push('\n');
        atomic_write(&plan_path, body.as_bytes())
            .context("writing PLAN.json")
    }

    fn spawn_worker(&self, shard: usize) -> Result<std::process::Child> {
        let log = std::fs::File::create(
            self.dir.join(format!("shard-{shard}.stderr.log")),
        )?;
        let mut cmd = Command::new(&self.exe);
        cmd.arg("sweep-worker")
            .arg("--dir")
            .arg(&self.dir)
            .arg("--shard")
            .arg(shard.to_string())
            .arg("--threads")
            .arg(self.threads.to_string())
            .stdout(Stdio::null())
            .stderr(Stdio::from(log));
        for (k, v) in &self.envs {
            cmd.env(k, v);
        }
        cmd.spawn().with_context(|| {
            format!("spawning worker for shard {shard} ({:?})", self.exe)
        })
    }

    /// Run (or resume) the sweep. Requires [`Self::init`] to have been
    /// called for this directory at some point.
    pub fn run(&self) -> Result<ShardedOutcome> {
        let plan = &self.plan;
        let mut manifest = Manifest::load_or_new(&self.dir, plan)?;

        // Re-verify checkpointed completions against the disk: a shard
        // whose artifact went missing, tore, or no longer matches its
        // recorded hash is *marked missing* and re-run.
        let stale: Vec<usize> = manifest
            .completed
            .iter()
            .filter(|&(&shard, recorded)| {
                match verify_artifact(&self.dir, plan, shard) {
                    Ok((_, sha)) => &sha != recorded,
                    Err(_) => true,
                }
            })
            .map(|(&shard, _)| shard)
            .collect();
        for shard in &stale {
            manifest.completed.remove(shard);
        }

        let reused: Vec<usize> = manifest.completed.keys().copied().collect();
        let pending_init: Vec<usize> = (0..plan.shards)
            .filter(|s| !manifest.completed.contains_key(s))
            .collect();
        // Shards about to be re-attempted get a clean dead-letter slate.
        let dead_before = manifest.dead_letter.len();
        manifest
            .dead_letter
            .retain(|d| !pending_init.contains(&d.shard));
        if !stale.is_empty() || manifest.dead_letter.len() != dead_before {
            manifest.save(&self.dir)?;
        }
        let mut pending: VecDeque<usize> = pending_init.into();

        let mut running: Vec<(usize, std::process::Child)> = Vec::new();
        let mut attempts: BTreeMap<usize, u32> = BTreeMap::new();
        let mut ran: Vec<usize> = Vec::new();
        let mut fresh_dead: Vec<DeadLetter> = Vec::new();

        loop {
            while running.len() < self.procs {
                let Some(shard) = pending.pop_front() else { break };
                running.push((shard, self.spawn_worker(shard)?));
            }
            if running.is_empty() {
                break;
            }
            let mut finished: Vec<(usize, std::process::ExitStatus)> =
                Vec::new();
            let mut still = Vec::new();
            for (shard, mut child) in running.drain(..) {
                match child.try_wait().context("polling worker")? {
                    Some(status) => finished.push((shard, status)),
                    None => still.push((shard, child)),
                }
            }
            running = still;
            if finished.is_empty() {
                std::thread::sleep(std::time::Duration::from_millis(10));
                continue;
            }
            for (shard, status) in finished {
                let verdict: Result<String> = if status.success() {
                    verify_artifact(&self.dir, plan, shard)
                        .map(|(_, sha)| sha)
                } else {
                    Err(anyhow!("worker exited with {status}"))
                };
                match verdict {
                    Ok(sha) => {
                        manifest.completed.insert(shard, sha);
                        manifest.save(&self.dir)?;
                        ran.push(shard);
                    }
                    Err(e) => {
                        let tries = attempts.entry(shard).or_insert(0);
                        *tries += 1;
                        if *tries <= self.retries {
                            log::warn!(
                                "shard {shard} attempt {tries} failed \
                                 ({e:#}); retrying"
                            );
                            pending.push_back(shard);
                        } else {
                            let dl = DeadLetter::for_shard(
                                plan,
                                shard,
                                *tries,
                                format!("{e:#}"),
                            );
                            manifest.dead_letter.push(dl.clone());
                            manifest.save(&self.dir)?;
                            fresh_dead.push(dl);
                        }
                    }
                }
            }
        }

        let merged = if manifest.completed.len() == plan.shards {
            let m = merge(&self.dir, plan)?;
            let mut body = json::to_string_pretty(&m.to_json(plan));
            body.push('\n');
            atomic_write(&self.dir.join("MERGED.json"), body.as_bytes())
                .context("writing MERGED.json")?;
            Some(m)
        } else {
            None
        };
        Ok(ShardedOutcome { merged, dead_letter: fresh_dead, ran, reused })
    }
}

/// Load a run directory as a worker (or a resuming orchestrator) sees
/// it: parse `PLAN.json`, check `scenario.toml` against the pinned
/// hash, parse the scenario (trace paths resolve against the recorded
/// `scenario_base`, defaulting to the run directory), and rebuild +
/// verify the plan.
pub fn load_run_dir(dir: &Path) -> Result<(ShardPlan, ScenarioConfig)> {
    let plan_path = dir.join("PLAN.json");
    let plan_text = std::fs::read_to_string(&plan_path)
        .with_context(|| format!("reading {}", plan_path.display()))?;
    let v = json::parse(&plan_text)
        .map_err(|e| anyhow!("{}: {e}", plan_path.display()))?;
    let scen_path = dir.join("scenario.toml");
    let scen_text = std::fs::read_to_string(&scen_path)
        .with_context(|| format!("reading {}", scen_path.display()))?;
    if sha256_hex(scen_text.as_bytes()) != v.req_str("scenario_sha256")? {
        bail!(
            "{} does not match the hash pinned in PLAN.json (scenario \
             edited after planning?)",
            scen_path.display()
        );
    }
    let base = v
        .get("scenario_base")
        .and_then(Value::as_str)
        .map(PathBuf::from)
        .unwrap_or_else(|| dir.to_path_buf());
    let scenario =
        ScenarioConfig::from_str_toml_with_base(&scen_text, Some(base.as_path()))?;
    let plan = ShardPlan::from_json(&v, &scenario, &scen_text)?;
    Ok((plan, scenario))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scenario() -> (ScenarioConfig, String) {
        let text = r#"
name = "shard-unit"
deadline_mins = 1800

[workload]
kind = "sleeper"
ks = [33, 55]
stage_secs = [100, 200]

[eviction]
plan = "poisson"
mean_mins = 45

[checkpoint]
method = "transparent"
interval_mins = 15
"#;
        (ScenarioConfig::from_str_toml(text).unwrap(), text.to_string())
    }

    fn plan_with(shards: usize, specs: &[&str]) -> ShardPlan {
        let (cfg, text) = scenario();
        let specs: Vec<String> = specs.iter().map(|s| s.to_string()).collect();
        ShardPlan::new(
            "unit",
            SeedStream::contiguous(0, 6),
            &specs,
            &cfg,
            &text,
            shards,
        )
        .unwrap()
    }

    #[test]
    fn plan_partitions_every_cell_exactly_once() {
        for shards in [1, 2, 3, 4, 5, 7, 12] {
            let plan = plan_with(shards, &["fixed", "young-daly"]);
            assert_eq!(plan.cells(), 12);
            let mut seen = vec![false; plan.cells()];
            let mut expected_lo = 0;
            for k in 0..plan.shards {
                let range = plan.shard_range(k);
                assert!(!range.is_empty(), "shard {k} empty at S={shards}");
                assert_eq!(range.start, expected_lo, "gap before shard {k}");
                expected_lo = range.end;
                for m in range {
                    assert!(!seen[m], "cell {m} in two shards");
                    seen[m] = true;
                }
            }
            assert_eq!(expected_lo, plan.cells());
            assert!(seen.iter().all(|&s| s));
        }
    }

    #[test]
    fn shard_count_is_clamped_to_cells() {
        let plan = plan_with(500, &["fixed"]);
        assert_eq!(plan.shards, 6, "6 cells can fill at most 6 shards");
        let plan = plan_with(0, &["fixed"]);
        assert_eq!(plan.shards, 1);
    }

    #[test]
    fn salted_streams_are_decorrelated_and_boundary_free() {
        let plain = SeedStream::contiguous(10, 8);
        assert_eq!(plain.iter().collect::<Vec<_>>(), (10..18).collect::<Vec<_>>());
        let salted = SeedStream::salted(10, 8, 0xfeed);
        let seeds: Vec<u64> = salted.iter().collect();
        // deterministic
        assert_eq!(seeds, salted.iter().collect::<Vec<_>>());
        // decorrelated from the contiguous range and from other salts
        assert!(seeds.iter().zip(10..18).all(|(&s, p)| s != p));
        let other: Vec<u64> = SeedStream::salted(10, 8, 0xbeef).iter().collect();
        assert!(seeds.iter().zip(&other).all(|(a, b)| a != b));
        // seeds are a function of global index only — identical however
        // the plan is sharded (shard boundaries never enter seed())
        let via_cells: Vec<u64> = (0..8)
            .map(|j| SeedStream::salted(10, 8, 0xfeed).seed(j))
            .collect();
        assert_eq!(seeds, via_cells);
    }

    #[test]
    fn invalid_combinations_are_filtered_with_reasons() {
        let text = "[checkpoint]\nmethod = \"none\"\n";
        let cfg = ScenarioConfig::from_str_toml(text).unwrap();
        let specs =
            vec!["base".to_string(), "young-daly".to_string(), "fixed".into()];
        let plan = ShardPlan::new(
            "f",
            SeedStream::contiguous(0, 2),
            &specs,
            &cfg,
            text,
            2,
        )
        .unwrap();
        // base has no controller, fixed is the no-op controller — both
        // run anywhere; young-daly needs transparent checkpointing
        let resolved: Vec<&str> =
            plan.configs.iter().map(|c| c.spec.as_str()).collect();
        assert_eq!(resolved, ["base", "fixed"]);
        assert_eq!(plan.skipped.len(), 1);
        assert_eq!(plan.skipped[0].spec, "young-daly");
        assert!(
            plan.skipped[0].reason.contains("transparent"),
            "{}",
            plan.skipped[0].reason
        );
        // all filtered → hard error
        let err = ShardPlan::new(
            "f",
            SeedStream::contiguous(0, 2),
            &["cost-aware:2".to_string()],
            &cfg,
            text,
            1,
        )
        .unwrap_err();
        assert!(err.to_string().contains("filtered out"), "{err}");
    }

    #[test]
    fn fingerprint_tracks_every_parameter() {
        let base = plan_with(3, &["fixed"]);
        let fp = |p: &ShardPlan| p.fingerprint().to_string();
        // run_id is a label, not part of the work
        let (cfg, text) = scenario();
        let renamed = ShardPlan::new(
            "other-name",
            SeedStream::contiguous(0, 6),
            &["fixed".to_string()],
            &cfg,
            &text,
            3,
        )
        .unwrap();
        assert_eq!(fp(&base), fp(&renamed));
        // every work-defining knob moves it
        assert_ne!(fp(&base), fp(&plan_with(4, &["fixed"])));
        assert_ne!(fp(&base), fp(&plan_with(3, &["young-daly"])));
        let salted = ShardPlan::new(
            "unit",
            SeedStream::salted(0, 6, 9),
            &["fixed".to_string()],
            &cfg,
            &text,
            3,
        )
        .unwrap();
        assert_ne!(fp(&base), fp(&salted));
        let edited = ShardPlan::new(
            "unit",
            SeedStream::contiguous(0, 6),
            &["fixed".to_string()],
            &cfg,
            &format!("{text}\n# edited"),
            3,
        )
        .unwrap();
        assert_ne!(fp(&base), fp(&edited));
    }

    #[test]
    fn plan_round_trips_through_json() {
        let (cfg, text) = scenario();
        let plan = plan_with(4, &["fixed", "cost-aware:1.5"]);
        let v = plan.to_json();
        let back = ShardPlan::from_json(&v, &cfg, &text).unwrap();
        assert_eq!(back.fingerprint(), plan.fingerprint());
        assert_eq!(back.seeds, plan.seeds);
        assert_eq!(back.shards, plan.shards);
        assert_eq!(back.configs, plan.configs);
        // tampering with a stored field is caught
        let mut tampered = v.clone();
        tampered.set("seed_count", 7u64);
        let err = ShardPlan::from_json(&tampered, &cfg, &text).unwrap_err();
        assert!(err.to_string().contains("fingerprint"), "{err}");
    }

    #[test]
    fn resharding_preserves_the_merged_digest() {
        let (cfg, _) = scenario();
        let run = |shards: usize| -> (String, Vec<CellRecord>) {
            let plan = plan_with(shards, &["fixed", "young-daly"]);
            let mut cells = Vec::new();
            for k in 0..plan.shards {
                let art = run_shard(&plan, &cfg, k, 2).unwrap();
                art.validate(&plan, k).unwrap();
                cells.extend(art.cells);
            }
            (
                fold_cell_shas(cells.iter().map(|c| c.digest_sha.as_str())),
                cells,
            )
        };
        let (d3, cells3) = run(3);
        let (d5, cells5) = run(5);
        assert_eq!(d3, d5, "shard count must be invisible in the merge");
        assert_eq!(cells3, cells5);
        // and the digest equals the in-process Sweep fold, per variant
        // in config-major cell order
        let mut digests: Vec<String> = Vec::new();
        for spec in ["fixed", "young-daly"] {
            let mut c = cfg.clone();
            ConfigVariant::parse(spec).unwrap().apply(&mut c);
            let runs = Experiment { cfg: c }
                .sweep()
                .seed_range(0, 6)
                .threads(2)
                .run()
                .unwrap();
            digests.extend(runs.iter().map(|r| run_digest(&r.result)));
        }
        assert_eq!(d3, fold_run_digests(digests.iter()));
    }

    #[test]
    fn artifact_round_trips_and_rejects_tampering() {
        let (cfg, _) = scenario();
        let plan = plan_with(3, &["fixed"]);
        let art = run_shard(&plan, &cfg, 1, 1).unwrap();
        let v = art.to_json();
        let back = ShardArtifact::from_json(&v).unwrap();
        back.validate(&plan, 1).unwrap();
        assert_eq!(back.cells, art.cells);
        // wrong shard id
        assert!(back.validate(&plan, 2).is_err());
        // a tampered seed fails validation
        let mut bad = back.clone();
        bad.cells[0].seed ^= 1;
        let err = bad.validate(&plan, 1).unwrap_err();
        assert!(err.to_string().contains("seed"), "{err}");
        // metrics survive the JSON round trip bit-exactly
        let text = json::to_string_pretty(&v);
        let reparsed =
            ShardArtifact::from_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(reparsed.cells, art.cells);
    }

    #[test]
    fn merge_rejects_missing_and_truncated_artifacts() {
        let (cfg, _) = scenario();
        let plan = plan_with(2, &["fixed"]);
        let dir = std::env::temp_dir().join(format!(
            "spoton-shard-unit-{}-{}",
            std::process::id(),
            crate::util::next_seq()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        for k in 0..plan.shards {
            let art = run_shard(&plan, &cfg, k, 1).unwrap();
            let mut body = json::to_string_pretty(&art.to_json());
            body.push('\n');
            atomic_write(&artifact_path(&dir, k), body.as_bytes()).unwrap();
        }
        let full = merge(&dir, &plan).unwrap();
        assert_eq!(full.cells.len(), plan.cells());
        assert_eq!(full.summaries.len(), 1);
        assert_eq!(full.summaries[0].runs, 6);
        // truncate shard 1 mid-file: parse fails → merge fails
        let path = artifact_path(&dir, 1);
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        let err = merge(&dir, &plan).unwrap_err();
        assert!(format!("{err:#}").contains("shard 1"), "{err:#}");
        // remove it entirely: still fails
        std::fs::remove_file(&path).unwrap();
        assert!(merge(&dir, &plan).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cluster_scenarios_shard_too() {
        let text = r#"
name = "shard-cluster-unit"
deadline_mins = 240000

[workload]
kind = "sleeper"
ks = [33]
stage_secs = [2]

[eviction]
plan = "poisson"
mean_mins = 6

[checkpoint]
method = "transparent"
interval_mins = 5

[cluster]
jobs = 6
capacity = 2
"#;
        let cfg = ScenarioConfig::from_str_toml(text).unwrap();
        let plan = ShardPlan::new(
            "cluster-unit",
            SeedStream::contiguous(0, 4),
            &[],
            &cfg,
            text,
            2,
        )
        .unwrap();
        assert_eq!(plan.configs[0].spec, "base");
        let a0 = run_shard(&plan, &cfg, 0, 1).unwrap();
        let a1 = run_shard(&plan, &cfg, 1, 2).unwrap();
        a0.validate(&plan, 0).unwrap();
        a1.validate(&plan, 1).unwrap();
        // equals the in-process ClusterSweep fold
        let runs = Experiment { cfg: cfg.clone() }
            .cluster_sweep()
            .seed_range(0, 4)
            .threads(2)
            .run()
            .unwrap();
        let folded =
            fold_run_digests(runs.iter().map(|r| cluster_digest(&r.result)));
        let sharded = fold_cell_shas(
            a0.cells
                .iter()
                .chain(a1.cells.iter())
                .map(|c| c.digest_sha.as_str()),
        );
        assert_eq!(folded, sharded);
        // cluster metrics aggregate over jobs
        assert!(a0.cells.iter().all(|c| c.metrics.completed));
        assert!(a0.cells[0].metrics.makespan_secs > 0.0);
    }

    #[test]
    fn timing_is_excluded_from_comparable_output() {
        // two artifacts for the same shard with different wall clocks
        // must agree on everything the merge consumes
        let (cfg, _) = scenario();
        let plan = plan_with(2, &["fixed"]);
        let mut a = run_shard(&plan, &cfg, 0, 1).unwrap();
        let mut b = run_shard(&plan, &cfg, 0, 2).unwrap();
        assert_eq!(a.cells, b.cells);
        a.wall_ms = 1;
        b.wall_ms = 99_999;
        assert_eq!(a.cells, b.cells, "wall_ms must not touch cells");
    }
}
