//! Bid frontier: what deadline attainment costs on a spiking spot market.
//!
//! ```bash
//! cargo run --release --example bid_frontier
//! ```
//!
//! Three fleet configurations race the same deadline-SLA job population
//! through the recorded `east-spike` price trace (the market opens at
//! 0.8× the spot level, doubles at 80 min, and keeps climbing):
//!
//! * **all-spot** — every job bids a fixed $0.10/h on the traced pool and
//!   loses the auction when the spike crosses the bid: outbid, evicted,
//!   and every replacement is born outbid again. Nobody finishes; every
//!   deadline is missed.
//! * **hybrid** — the autoscaler ([`spoton::autoscale`]) bids the
//!   25th-percentile of the traced factor stream (Khatua-style) and,
//!   the moment the spike makes that bid non-viable, shifts replacements
//!   onto a never-evicting on-demand pool. Every deadline holds.
//! * **on-demand** — the whole population runs at the undiscounted
//!   catalog price. Every deadline holds, at the highest cost.
//!
//! The run reduces each population to a [`spoton::report::frontier`]
//! point and hard-asserts the headline: the hybrid holds 100% attainment
//! at a fraction of the all-on-demand cost, and the all-on-demand point
//! is Pareto-dominated.

use spoton::cloud::trace::PoolTrace;
use spoton::config::{
    AutoscaleCfg, BidPolicyCfg, ClusterCfg, EvictionPlanCfg,
    PlacementPolicyCfg, PoolCfg, PoolPricingCfg,
};
use spoton::metrics::EventKind;
use spoton::report::{render_frontier, sla_frontier};
use spoton::sim::cluster::ClusterResult;
use spoton::sim::experiment::Experiment;
use spoton::simclock::SimDuration;

/// Concurrent deadline-SLA jobs per run.
const JOBS: usize = 6;
/// Seeded runs per configuration.
const SEEDS: usize = 3;
const SEED0: u64 = 101;

/// The shared scenario: 6 sleeper jobs of ~90 min work each,
/// transparent checkpoints every 15 min, a 6 h per-job SLA, and an 8 h
/// abort deadline so the losing configuration terminates.
fn base() -> Experiment {
    let mut exp = Experiment::table1()
        .named("bid-frontier")
        .transparent(SimDuration::from_mins(15))
        .deadline(SimDuration::from_mins(480))
        .placement(PlacementPolicyCfg::CheapestSpot);
    exp.cfg.workload.ks = vec![40, 50];
    exp.cfg.workload.stage_secs = vec![2700, 2700];
    exp.cfg.cluster = Some(ClusterCfg::with_count(JOBS));
    exp.cfg.job_deadline = Some(SimDuration::from_mins(360));
    exp
}

/// The traced spot pool: east-spike pricing plus the trace's recorded
/// eviction offsets, sized so the whole population fits.
fn east_pool(trace: &PoolTrace) -> PoolCfg {
    PoolCfg::named("east")
        .pricing(PoolPricingCfg::Trace(trace.price.clone()))
        .eviction(EvictionPlanCfg::Trace { offsets: trace.evictions.clone() })
        .capacity(JOBS as u32)
}

/// The undiscounted fallback: never evicted, never outbid.
fn ondemand_pool() -> PoolCfg {
    PoolCfg::named("ondemand").spot(false).capacity(JOBS as u32)
}

fn run(exp: &Experiment) -> anyhow::Result<Vec<ClusterResult>> {
    let runs = exp.cluster_sweep().seed_range(SEED0, SEEDS).run()?;
    Ok(runs.into_iter().map(|r| r.result).collect())
}

fn main() -> anyhow::Result<()> {
    // Compiled in so the example runs from any working directory; the
    // same file drives `scenarios/bid_storm.toml` through `spoton check`.
    let trace =
        PoolTrace::parse(include_str!("../traces/east-spike.trace"))?;

    // 1. All-spot with a static $0.10/h bid: the 80-min spike (0.8× →
    //    1.6× of $0.076/h ≈ $0.1216/h) crosses it and the market never
    //    comes back down.
    let all_spot = run(&base().pool(east_pool(&trace).bid(0.10)))?;

    // 2. Hybrid: a bottom-quantile bid survives the calm market, and the
    //    spike flips placement to the on-demand pool via NoViableBid.
    let mut hybrid_exp =
        base().pool(east_pool(&trace)).pool(ondemand_pool());
    hybrid_exp.cfg.autoscale = Some(AutoscaleCfg {
        policy: BidPolicyCfg::Percentile { q: 0.25 },
        on_demand_pool: "ondemand".into(),
        slack: SimDuration::from_mins(60),
        max_queue: 4,
    });
    let hybrid = run(&hybrid_exp)?;

    // 3. All-on-demand: the attainment ceiling and the cost ceiling.
    let on_demand = run(&base().pool(ondemand_pool()))?;

    let groups: Vec<(&str, Vec<ClusterResult>)> = vec![
        ("all-spot", all_spot),
        ("hybrid", hybrid),
        ("on-demand", on_demand),
    ];
    let points = sla_frontier(&groups);
    println!("cost-vs-SLA frontier over {SEEDS} seeded runs:\n");
    print!("{}", render_frontier(&points));

    let by_label = |l: &str| {
        points.iter().find(|p| p.label == l).expect("label present")
    };
    let (spot_pt, hybrid_pt, od_pt) =
        (by_label("all-spot"), by_label("hybrid"), by_label("on-demand"));

    // The all-spot arm lost the auction: outbid jobs thrash until the
    // abort deadline and every SLA is missed.
    assert!(spot_pt.misses > 0, "the spike must outbid the $0.10 bid");
    assert!(
        spot_pt.sla.expect("verdicts recorded") < 0.5,
        "all-spot cannot hold the SLA through the spike"
    );

    // The hybrid held the SLA the all-spot arm lost...
    assert!(
        hybrid_pt.sla.expect("verdicts recorded") >= 0.99,
        "the hybrid must hold >= 99% attainment"
    );
    // ...at a fraction of the on-demand price.
    assert!(
        hybrid_pt.mean_cost < 0.75 * od_pt.mean_cost,
        "hybrid (${:.4}) must undercut on-demand (${:.4}) by >= 25%",
        hybrid_pt.mean_cost,
        od_pt.mean_cost
    );
    assert!(!hybrid_pt.dominated, "the hybrid sits on the frontier");
    assert!(
        od_pt.dominated,
        "equal attainment at higher cost: on-demand is dominated"
    );

    // The mechanism, not just the outcome: the hybrid's jobs really were
    // outbid on spot and really were shifted by the autoscaler.
    let hybrid_results = &groups
        .iter()
        .find(|(l, _)| *l == "hybrid")
        .expect("hybrid group")
        .1;
    let outbids: usize = hybrid_results
        .iter()
        .flat_map(|r| &r.jobs)
        .map(|j| j.result.timeline.count(EventKind::PoolOutbid))
        .sum();
    let shifts: usize = hybrid_results
        .iter()
        .map(|r| r.timeline.count(EventKind::AutoscaleShift))
        .sum();
    assert!(outbids > 0, "the spike must outbid the percentile bid");
    assert!(shifts > 0, "the autoscaler must shift the outbid jobs");

    println!(
        "\nhybrid: {:.0}% attainment at {:.0}% of the on-demand cost \
         ({} outbids absorbed, {} autoscale shifts)",
        hybrid_pt.sla.unwrap_or(0.0) * 100.0,
        100.0 * hybrid_pt.mean_cost / od_pt.mean_cost,
        outbids,
        shifts
    );
    Ok(())
}
