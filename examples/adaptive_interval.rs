//! Adaptive checkpoint-interval controllers vs the paper's fixed cadence.
//!
//! ```bash
//! cargo run --release --example adaptive_interval
//! ```
//!
//! Two demonstrations of the `policy/` subsystem:
//!
//! 1. **Young/Daly dominates the fixed interval on an eviction storm.**
//!    A 600-seed deterministic sweep over Poisson evictions (mean 35 min)
//!    with a 10 s notice the 3 GiB image can never beat — termination
//!    checkpoints all fail, so the periodic cadence is the only
//!    protection. The paper's fixed 30-minute interval loses up to a full
//!    interval of work per eviction; `young-daly` re-derives
//!    `√(2·δ·MTBF)` online (≈ 5 min here) and must come out strictly
//!    ahead: lower mean cost at no worse p95 makespan. `cost-aware`
//!    matches `young-daly` exactly on this static market (price factor
//!    1.0) — the price term is inert until the market moves.
//!
//! 2. **Cost-aware cadence follows the traced market.** A single run on
//!    `traces/east-spike.trace` (20% discount until the price doubles at
//!    the 80-minute mark, four early evictions): while the pool is cheap
//!    the controller checkpoints every few minutes; once the spike makes
//!    every frozen second expensive, the cadence stretches out — the
//!    checkpoint rate before the spike must exceed the rate after it.

use spoton::cloud::trace::PoolTrace;
use spoton::config::{EvictionPlanCfg, IntervalControllerCfg, PoolCfg, PoolPricingCfg};
use spoton::metrics::EventKind;
use spoton::report::policy::{
    render_controller_comparison, summarize_controllers,
};
use spoton::sim::experiment::Experiment;
use spoton::simclock::{SimDuration, SimTime};
use std::path::Path;
use std::time::Instant;

const SEEDS: usize = 600;

/// Vendored traces live next to the workspace root, independent of the
/// invocation directory (cargo test/bench chdir into `rust/`).
fn trace_path(name: &str) -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../traces").join(name)
}

fn storm() -> Experiment {
    Experiment::table1()
        .named("adaptive-storm")
        .eviction_poisson(SimDuration::from_mins(35))
        .transparent(SimDuration::from_mins(30))
        .notice(SimDuration::from_secs(10))
        .deadline(SimDuration::from_hours(30))
}

fn main() -> anyhow::Result<()> {
    // ---- 1. Young/Daly vs fixed over a seeded eviction storm ----
    println!(
        "Eviction storm: poisson mean 35 min, 10 s notice (termination \
         checkpoints always fail), {SEEDS} seeds per controller\n"
    );
    let t0 = Instant::now();
    let sweeps = storm().sweep().seed_range(0, SEEDS).run_controllers(&[
        IntervalControllerCfg::Fixed,
        IntervalControllerCfg::young_daly(),
        IntervalControllerCfg::cost_aware(1.0),
    ])?;
    let entries = summarize_controllers(&sweeps);
    println!(
        "{} runs in {:.2?}\n",
        SEEDS * entries.len(),
        t0.elapsed()
    );
    print!("{}", render_controller_comparison(&entries));

    let fixed = &entries[0];
    let yd = &entries[1];
    let ca = &entries[2];
    anyhow::ensure!(
        yd.dist.total_cost.mean < fixed.dist.total_cost.mean,
        "young-daly mean cost ${:.4} must undercut fixed ${:.4}",
        yd.dist.total_cost.mean,
        fixed.dist.total_cost.mean
    );
    anyhow::ensure!(
        yd.dist.makespan_secs.p95 <= fixed.dist.makespan_secs.p95,
        "young-daly p95 makespan {:.0}s must not exceed fixed {:.0}s",
        yd.dist.makespan_secs.p95,
        fixed.dist.makespan_secs.p95
    );
    // static market: the price term is inert, cost-aware == young-daly
    anyhow::ensure!(
        (ca.dist.total_cost.mean - yd.dist.total_cost.mean).abs() < 1e-12
            && ca.dist.makespan_secs.p50 == yd.dist.makespan_secs.p50,
        "cost-aware must match young-daly on a static market"
    );
    println!(
        "young-daly strictly dominates: mean cost ${:.4} -> ${:.4} \
         ({:.1}% cheaper), p95 makespan {} -> {}\n",
        fixed.dist.total_cost.mean,
        yd.dist.total_cost.mean,
        100.0 * (1.0 - yd.dist.total_cost.mean / fixed.dist.total_cost.mean),
        SimDuration::from_secs_f64(fixed.dist.makespan_secs.p95).hms(),
        SimDuration::from_secs_f64(yd.dist.makespan_secs.p95).hms(),
    );

    // ---- 2. Cost-aware cadence across the east-spike market ----
    let trace = PoolTrace::load(&trace_path("east-spike.trace"))?;
    let spike_at = SimTime::ZERO + SimDuration::from_mins(80);
    let mut pool = PoolCfg::named("east-spike")
        .pricing(PoolPricingCfg::Trace(trace.price));
    if !trace.evictions.is_empty() {
        pool = pool
            .eviction(EvictionPlanCfg::Trace { offsets: trace.evictions });
    }
    let run = Experiment::table1()
        .named("cost-aware-spike")
        .transparent(SimDuration::from_mins(30))
        .adaptive(IntervalControllerCfg::cost_aware(1.0))
        .pool(pool)
        .run_sleeper()?;
    anyhow::ensure!(run.completed, "{}", run.summary());

    let periodic: Vec<SimTime> = run
        .timeline
        .events()
        .iter()
        .filter(|e| {
            e.kind == EventKind::CheckpointCommitted
                && e.detail.starts_with("periodic")
        })
        .map(|e| e.at)
        .collect();
    let pre = periodic.iter().filter(|&&at| at < spike_at).count();
    let post = periodic.len() - pre;
    let pre_rate = pre as f64 / spike_at.as_secs_f64() * 3600.0;
    let post_secs = run.total.as_secs_f64() - spike_at.as_secs_f64();
    let post_rate = post as f64 / post_secs * 3600.0;
    println!(
        "traces/east-spike.trace under cost-aware/1 (price x0.8 until \
         T+1:20:00, x1.6 after):\n  pre-spike:  {pre} periodic ckpts in \
         {} ({pre_rate:.1}/h)\n  post-spike: {post} periodic ckpts in {} \
         ({post_rate:.1}/h)",
        SimDuration::from_mins(80),
        SimDuration::from_secs_f64(post_secs),
    );
    anyhow::ensure!(
        pre_rate > post_rate,
        "checkpoints must cluster in the cheap window \
         ({pre_rate:.2}/h pre vs {post_rate:.2}/h post)"
    );
    println!(
        "\nthe cadence followed the market: {:.1}x more frequent while \
         the pool traded at a discount.",
        pre_rate / post_rate
    );
    Ok(())
}
