//! A contended cluster under a price spike: the multiplexed engine's
//! headline scenario.
//!
//! ```bash
//! cargo run --release --example contended_cluster
//! ```
//!
//! Forty jobs arrive five minutes apart on a two-pool fleet:
//!
//! * `east` — capacity 8, 20% below catalog until a capacity crunch
//!   more than doubles the price at the 60-minute mark; the crunch
//!   clears at minute 180;
//! * `west` — capacity 2, steady at catalog.
//!
//! Pre-spike, `CheapestSpot` admits everyone into east and the cluster
//! runs without queueing. The spike flips the price order: arrivals
//! funnel into west, west's two slots saturate almost immediately, and
//! the admission queue grows for the whole spike — east's slots sit
//! idle because the policy (correctly) refuses to place new work at the
//! spiked price, and FIFO head-of-line blocking holds the line behind
//! the west-bound head. When the spike clears, placements flip back to
//! east's eight slots and the backlog drains. Every admission decision,
//! queue event and price epoch comes off **one** event queue around
//! **one** live fleet — the cluster-wide view the per-run engine could
//! never see.

use spoton::cloud::trace::{PricePoint, PriceTrace};
use spoton::config::{ClusterCfg, PlacementPolicyCfg, PoolCfg, PoolPricingCfg};
use spoton::metrics::EventKind;
use spoton::sim::experiment::Experiment;
use spoton::simclock::SimDuration;

const SPIKE_START_MIN: u64 = 60;
const SPIKE_END_MIN: u64 = 180;

fn main() -> anyhow::Result<()> {
    let spike = PriceTrace::new(vec![
        PricePoint { offset: SimDuration::ZERO, factor: 0.8 },
        PricePoint {
            offset: SimDuration::from_mins(SPIKE_START_MIN),
            factor: 2.0,
        },
        PricePoint {
            offset: SimDuration::from_mins(SPIKE_END_MIN),
            factor: 0.8,
        },
    ])?;
    let mut exp = Experiment::table1()
        .named("contended-cluster")
        .scale_stages(0.1)
        .transparent(SimDuration::from_mins(10))
        .deadline(SimDuration::from_hours(400))
        .pool(
            PoolCfg::named("east")
                .capacity(8)
                .pricing(PoolPricingCfg::Trace(spike)),
        )
        .pool(PoolCfg::named("west").capacity(2))
        .placement(PlacementPolicyCfg::CheapestSpot);
    exp.cfg.cluster = Some(ClusterCfg::with_count(40).arrival(
        spoton::config::ArrivalCfg::Uniform {
            spacing: SimDuration::from_mins(5),
        },
    ));

    let r = exp.run_cluster_sleeper()?;
    println!("{}\n", r.summary());
    println!(
        "peak in flight: {} cluster-wide, {:?} per pool (east cap 8, west \
         cap 2)",
        r.peak_in_flight, r.peak_in_flight_per_pool
    );

    // every job eventually finished: the queue drained after the spike
    assert_eq!(r.completed_jobs(), 40, "queue must drain: {}", r.summary());
    assert!(r.timeline.is_monotone());
    assert!(r.peak_in_flight_per_pool[0] <= 8);
    assert!(r.peak_in_flight_per_pool[1] <= 2);

    // pre-spike the cluster is underloaded: nobody queues before the
    // price flips
    let spike_start = SimDuration::from_mins(SPIKE_START_MIN).as_millis();
    let spike_end = SimDuration::from_mins(SPIKE_END_MIN).as_millis();
    let queued_at: Vec<u64> = r
        .timeline
        .events()
        .iter()
        .filter(|e| e.kind == EventKind::JobQueued)
        .map(|e| e.at.as_millis())
        .collect();
    assert!(!queued_at.is_empty(), "the spike must force queueing");
    assert!(
        queued_at.iter().all(|&at| at > spike_start),
        "no queueing before the spike: {}",
        r.summary()
    );
    let queued_in_spike = queued_at
        .iter()
        .filter(|&&at| at > spike_start && at < spike_end)
        .count();
    println!(
        "\n{} jobs queued during the spike window ({}–{} min), {} queued \
         admissions total",
        queued_in_spike,
        SPIKE_START_MIN,
        SPIKE_END_MIN,
        r.queued_admissions()
    );
    assert!(
        queued_in_spike >= 8,
        "the backlog must genuinely build during the spike"
    );

    // while east is spiked, every queue admission lands in west; once
    // the spike clears, placements flip back to east and the backlog
    // drains through its eight slots
    let mut west_during_spike = 0usize;
    let mut east_after_spike = 0usize;
    for e in r.timeline.events() {
        if e.kind != EventKind::JobAdmitted {
            continue;
        }
        let at = e.at.as_millis();
        if at > spike_start && at < spike_end {
            assert!(
                e.detail.ends_with("-> west"),
                "mid-spike admission must avoid the spiked pool: {} @ {at}",
                e.detail
            );
            west_during_spike += 1;
        } else if at > spike_end && e.detail.ends_with("-> east") {
            east_after_spike += 1;
        }
    }
    assert!(
        west_during_spike > 0,
        "west must take the mid-spike spillover"
    );
    assert!(
        east_after_spike > 0,
        "the post-spike drain must flow back into east"
    );
    println!(
        "{west_during_spike} mid-spike admissions into west, \
         {east_after_spike} post-spike admissions back into east"
    );

    // the backlog outlived the spike: the last job finished well after
    // the price recovered
    assert!(
        r.makespan > SimDuration::from_mins(SPIKE_END_MIN),
        "drain must extend past the spike ({} makespan)",
        r.makespan
    );
    println!(
        "makespan {} — queue grew for the whole spike, drained in {} after \
         the price recovered",
        r.makespan,
        r.makespan - SimDuration::from_mins(SPIKE_END_MIN)
    );
    Ok(())
}
