//! End-to-end driver: the full three-layer stack on a real workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example metaspades_spot
//! ```
//!
//! This is the paper's case study, reproduced end to end (DESIGN.md §5,
//! "End-to-end validation"):
//!
//! 1. loads the AOT-compiled JAX/Pallas artifacts through PJRT (L1/L2);
//! 2. assembles a synthetic metagenome with the MiniMeta multi-k pipeline
//!    (K33→K127), every k-mer counted and every denoise sweep executed by
//!    the compiled kernels (real compute on the request path, no Python);
//! 3. runs it twice: uninterrupted baseline, then on spot instances with
//!    evictions every 90 min + transparent checkpoints every 30 min, on a
//!    real directory-backed NFS share;
//! 4. proves the headline property: the evicted+restored run produces the
//!    *bit-identical* final assembly state, for 74% less money.

use spoton::runtime::Runtime;
use spoton::sim::experiment::Experiment;
use spoton::simclock::SimDuration;
use std::cell::RefCell;
use std::rc::Rc;

fn main() -> anyhow::Result<()> {
    let dir = spoton::runtime::default_artifacts_dir();
    let rt = Rc::new(RefCell::new(Runtime::load(&dir)?));
    {
        let r = rt.borrow();
        let g = r.geometry();
        println!(
            "artifacts: {} compiled kernels, B={} buckets, {} reads/call, \
             ks={:?}",
            r.manifest().artifacts.len(),
            g.num_buckets,
            g.reads_per_call,
            g.ks
        );
    }

    // A smaller read set than the bench default keeps this example snappy
    // while still running hundreds of PJRT calls.
    let size = |mut e: Experiment| {
        e.cfg.workload.total_reads = 8 * 1024;
        e.cfg.workload.denoise_sweeps = 8;
        e
    };

    println!("\n[1/2] uninterrupted baseline (on-demand, Spot-on OFF)…");
    let t0 = std::time::Instant::now();
    let baseline = size(Experiment::table1()
        .named("baseline")
        .spoton_off()
        .ondemand())
    .run_minimeta(rt.clone())?;
    println!(
        "  {} — {:?} wall for {} of simulated cloud time",
        baseline.summary(),
        t0.elapsed(),
        baseline.total
    );

    println!(
        "\n[2/2] spot run: evictions every 90 min, transparent ckpt every \
         30 min, real NFS share…"
    );
    let share = std::env::temp_dir().join(format!(
        "spoton-metaspades-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&share);
    let t0 = std::time::Instant::now();
    let spot = size(Experiment::table1()
        .named("spot+transparent")
        .eviction_every(SimDuration::from_mins(90))
        .transparent(SimDuration::from_mins(30)))
    .run_minimeta_on_nfs(rt.clone(), &share)?;
    println!(
        "  {} — {:?} wall",
        spot.summary(),
        t0.elapsed()
    );

    println!("\nPer-stage wall time:");
    println!("  stage   baseline   spot+ckpt");
    for ((label, base_d), (_, spot_d)) in
        baseline.stage_times.iter().zip(&spot.stage_times)
    {
        println!("  {label:<6}  {:>8}   {:>8}", base_d.hms(), spot_d.hms());
    }

    println!("\nTimeline of the spot run:");
    print!("{}", spot.timeline);

    println!("\nInvoices:");
    println!("baseline (on-demand):\n{}", baseline.invoice);
    println!("spot + transparent:\n{}", spot.invoice);

    // --- the headline checks -------------------------------------------
    assert!(spot.completed, "spot run must complete despite evictions");
    assert!(spot.evictions >= 2, "90-min evictions over a ~3 h run");
    assert_eq!(
        baseline.final_fingerprint, spot.final_fingerprint,
        "restored assembly diverged from the uninterrupted run!"
    );
    let saving = 1.0 - spot.total_cost() / baseline.total_cost();
    println!(
        "\nRESULT: bit-identical assembly state after {} eviction(s); \
         cost {} vs {} on-demand ({:.0}% saved; paper: 77%)",
        spot.evictions,
        spoton::util::fmt::dollars(spot.total_cost()),
        spoton::util::fmt::dollars(baseline.total_cost()),
        saving * 100.0
    );
    let _ = std::fs::remove_dir_all(&share);
    Ok(())
}
