//! Trace-driven spot markets: a replayed price history flips the
//! `CheapestSpot` winner mid-run.
//!
//! ```bash
//! cargo run --release --example trace_replay
//! ```
//!
//! Two pools replay the vendored traces under `traces/`:
//!
//! * `east-spike` — 20% below catalog until a capacity crunch doubles
//!   the price at the 80-minute mark; evicts each of its first four
//!   instances after 40 minutes of uptime;
//! * `west-calm` — steady at a 5% premium, softening after two hours;
//!   never evicted.
//!
//! `CheapestSpot` chases the east discount through the first eviction,
//! but the replacement decided after the spike lands in west — the same
//! policy, re-deciding as the market moves. The instance that straddles
//! the spike is billed piecewise: one invoice line item per price
//! segment. `StickyPool` (the paper's single-scale-set behaviour) rides
//! east through every eviction and pays the spiked price for the rest of
//! the run.

use spoton::cloud::trace::PoolTrace;
use spoton::config::{EvictionPlanCfg, PlacementPolicyCfg, PoolCfg, PoolPricingCfg};
use spoton::metrics::EventKind;
use spoton::report::fleet::{
    render_policy_comparison, render_pool_breakdown, render_price_timeline,
};
use spoton::sim::experiment::Experiment;
use spoton::sim::RunResult;
use spoton::simclock::SimDuration;
use std::path::Path;

/// Vendored traces live next to the workspace root, independent of the
/// invocation directory (cargo test/bench chdir into `rust/`).
fn trace_path(name: &str) -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../traces").join(name)
}

fn traced_pool(name: &str, trace_file: &str) -> anyhow::Result<PoolCfg> {
    let trace = PoolTrace::load(&trace_path(trace_file))?;
    let mut pool =
        PoolCfg::named(name).pricing(PoolPricingCfg::Trace(trace.price));
    if !trace.evictions.is_empty() {
        pool = pool
            .eviction(EvictionPlanCfg::Trace { offsets: trace.evictions });
    }
    Ok(pool)
}

fn market(policy: PlacementPolicyCfg) -> anyhow::Result<Experiment> {
    Ok(Experiment::table1()
        .named("trace-replay")
        .transparent(SimDuration::from_mins(15))
        .seed(7)
        .pool(traced_pool("east-spike", "east-spike.trace")?)
        .pool(traced_pool("west-calm", "west-calm.trace")?)
        .placement(policy))
}

fn main() -> anyhow::Result<()> {
    let cheapest = market(PlacementPolicyCfg::CheapestSpot)?.run_sleeper()?;
    let sticky = market(PlacementPolicyCfg::Sticky)?.run_sleeper()?;

    println!("Replayed market (traces/east-spike.trace, west-calm.trace):\n");
    print!("{}", render_price_timeline(&cheapest));

    println!("\nCheapestSpot under the moving market:\n");
    print!("{}", render_pool_breakdown(&cheapest));

    // the market flip moved the workload: first placement chases the
    // east discount, the post-spike placement lands in west
    let placements: Vec<&str> = cheapest
        .timeline
        .events()
        .iter()
        .filter(|e| e.kind == EventKind::PlacementDecided)
        .map(|e| e.detail.as_ref())
        .collect();
    assert!(
        placements.first().expect("≥1 placement").contains("east"),
        "first placement should chase the discount: {placements:?}"
    );
    assert!(
        placements.last().expect("≥1 placement").contains("west"),
        "post-spike placement should flip to west: {placements:?}"
    );

    // piecewise billing: the instance straddling the spike books one
    // line item per price segment
    let vm_items = cheapest
        .invoice
        .items
        .iter()
        .filter(|i| i.resource.starts_with("vm/"))
        .count();
    assert!(
        vm_items > cheapest.instances as usize,
        "straddling instances should book >1 segment ({vm_items} items, \
         {} instances)",
        cheapest.instances
    );
    let attributed: f64 =
        cheapest.pool_stats.iter().map(|p| p.compute_cost).sum();
    assert!(
        (attributed - cheapest.compute_cost).abs() < 1e-9,
        "pool attribution must sum to the run's compute cost"
    );

    println!("\nAgainst the paper's sticky placement:\n");
    let rows: Vec<(&str, &RunResult)> =
        vec![("cheapest-spot", &cheapest), ("sticky", &sticky)];
    print!("{}", render_policy_comparison(&rows));

    assert!(
        cheapest.total_cost() < sticky.total_cost(),
        "re-deciding on the moving price must beat sticky (${:.4} vs ${:.4})",
        cheapest.total_cost(),
        sticky.total_cost()
    );
    println!(
        "\ncheapest-spot vs sticky: {} vs {} makespan, ${:.4} vs ${:.4} — \
         {:.0}% cheaper by leaving the spiked pool when the trace turns \
         against it.",
        cheapest.total.hms(),
        sticky.total.hms(),
        cheapest.total_cost(),
        sticky.total_cost(),
        (1.0 - cheapest.total_cost() / sticky.total_cost()) * 100.0
    );
    Ok(())
}
