//! Eviction storm: Poisson spot-market churn + the requeue scheduler.
//!
//! ```bash
//! cargo run --release --example eviction_storm
//! ```
//!
//! The paper injects evictions at fixed intervals; real spot markets are
//! burstier. This example runs the protected workload under Poisson
//! eviction storms of increasing severity, then pushes a batch of jobs
//! through the Slurm-style requeue scheduler (paper §II's "separate
//! job/resource scheduler" path).

use spoton::report::table::TextTable;
use spoton::sched::{Job, RequeueScheduler};
use spoton::sim::experiment::Experiment;
use spoton::simclock::SimDuration;

fn main() -> anyhow::Result<()> {
    // 1. Poisson storm severity sweep.
    println!("Poisson eviction storms (transparent 15m checkpoints):\n");
    let mut t = TextTable::new(&[
        "Mean uptime", "Evictions", "Total time", "vs baseline", "Cost",
    ]);
    let baseline = Experiment::table1().spoton_off().run_sleeper()?;
    for mean_min in [240u64, 120, 60, 30, 15] {
        let r = Experiment::table1()
            .named("storm")
            .eviction_poisson(SimDuration::from_mins(mean_min))
            .transparent(SimDuration::from_mins(15))
            .deadline(SimDuration::from_hours(24))
            .seed(4242)
            .run_sleeper()?;
        assert!(r.completed, "transparent must survive the storm");
        t.row(&[
            format!("{mean_min} min"),
            r.evictions.to_string(),
            r.total.hms(),
            format!(
                "{:+.1}%",
                (r.total.as_millis() as f64
                    / baseline.total.as_millis() as f64
                    - 1.0)
                    * 100.0
            ),
            spoton::util::fmt::dollars(r.total_cost()),
        ]);
    }
    print!("{}", t.render());

    // 2. A trace replay: an afternoon of real-feeling spot churn.
    println!("\nTrace replay (uptime offsets 73m, 22m, 48m, 95m, …):\n");
    let trace: Vec<SimDuration> = [73u64, 22, 48, 95, 31, 180, 60]
        .iter()
        .map(|m| SimDuration::from_mins(*m))
        .collect();
    let r = Experiment::table1()
        .named("trace")
        .eviction_trace(trace)
        .transparent(SimDuration::from_mins(15))
        .deadline(SimDuration::from_hours(24))
        .run_sleeper()?;
    println!("  {}", r.summary());
    assert!(r.completed);

    // 3. Requeue scheduler: a small batch queue of protected jobs.
    println!("\nRequeue scheduler (batch of 4 jobs, single spot slot):\n");
    let mk_jobs = || -> Vec<Job> {
        (0..4)
            .map(|i| Job {
                id: i,
                name: format!("assembly-{i}"),
                experiment: Experiment::table1()
                    .named("queued")
                    .eviction_every(SimDuration::from_mins(75))
                    .transparent(SimDuration::from_mins(15))
                    .seed(100 + i as u64),
            })
            .collect()
    };
    let sched = RequeueScheduler {
        requeue_delay: SimDuration::from_secs(300),
        max_attempts: 8,
        slots: 1,
        fleet: None,
    };
    let records = sched.run(mk_jobs())?;
    let mut t = TextTable::new(&[
        "Job", "Attempts", "Evictions", "Wait", "Turnaround", "Cost", "Done",
    ]);
    for r in &records {
        t.row(&[
            r.name.clone(),
            r.attempts.to_string(),
            r.evictions.to_string(),
            r.wait().hms(),
            r.turnaround().hms(),
            spoton::util::fmt::dollars(r.cost),
            if r.completed { "yes" } else { "NO" }.to_string(),
        ]);
    }
    print!("{}", t.render());
    assert!(records.iter().all(|r| r.completed));
    let makespan = |rs: &[spoton::sched::JobRecord]| {
        rs.iter().map(|r| r.finished_at).max().unwrap()
    };
    let serial_makespan = makespan(&records);

    // 4. Same batch on a 2-slot cluster: jobs share the event queue and
    //    run concurrently, so the makespan roughly halves.
    println!("\nSame batch, 2 concurrent spot slots:\n");
    let wide = RequeueScheduler {
        requeue_delay: SimDuration::from_secs(300),
        max_attempts: 8,
        slots: 2,
        fleet: None,
    };
    let (records2, timeline) = wide.run_with_timeline(mk_jobs())?;
    assert!(records2.iter().all(|r| r.completed));
    let wide_makespan = makespan(&records2);
    println!(
        "  makespan 1 slot: {}   2 slots: {}   ({} job-lifecycle events)",
        serial_makespan,
        wide_makespan,
        timeline.events().len()
    );
    assert!(wide_makespan < serial_makespan);
    println!("\nall jobs completed under continuous spot churn");
    Ok(())
}
