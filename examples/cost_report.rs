//! Cost exploration: what drives the savings in Fig 2?
//!
//! ```bash
//! cargo run --release --example cost_report
//! ```
//!
//! Reprices the paper's scenario across VM sizes, spot discounts and NFS
//! provisioning, and prints where the crossover between "protect on spot"
//! and "just pay for on-demand" sits.

use spoton::cloud::pricing::PriceBook;
use spoton::report::table::TextTable;
use spoton::sim::experiment::Experiment;
use spoton::simclock::SimDuration;

fn main() -> anyhow::Result<()> {
    // 1. Per-size cost table at the paper's eviction/checkpoint settings.
    let book = PriceBook::default();
    println!("Cost per VM size (evict 90m / transparent 30m vs on-demand):\n");
    let mut t = TextTable::new(&[
        "VM size", "On-demand", "Spot+ckpt", "Saving",
    ]);
    for size in book.sizes() {
        let mut od = Experiment::table1().spoton_off().ondemand();
        od.cfg.cloud.vm_size = size.name.clone();
        let od = od.run_sleeper()?;
        let mut spot = Experiment::table1()
            .eviction_every(SimDuration::from_mins(90))
            .transparent(SimDuration::from_mins(30));
        spot.cfg.cloud.vm_size = size.name.clone();
        let spot = spot.run_sleeper()?;
        t.row(&[
            size.name.clone(),
            spoton::util::fmt::dollars(od.total_cost()),
            spoton::util::fmt::dollars(spot.total_cost()),
            format!(
                "{:.1}%",
                (1.0 - spot.total_cost() / od.total_cost()) * 100.0
            ),
        ]);
    }
    print!("{}", t.render());

    // 2. Sensitivity: NFS provisioning is a fixed monthly cost — small
    //    next to compute for a 3 h run, dominant if you keep the share
    //    forever. Show the provisioned-size sweep.
    println!("\nNFS provisioning sweep (share kept only for the run):\n");
    let mut t = TextTable::new(&[
        "Provisioned", "Storage cost", "Total", "Saving vs on-demand",
    ]);
    let od = Experiment::table1().spoton_off().ondemand().run_sleeper()?;
    for gib in [100.0f64, 250.0, 500.0, 1000.0] {
        let mut e = Experiment::table1()
            .eviction_every(SimDuration::from_mins(90))
            .transparent(SimDuration::from_mins(30));
        e.cfg.storage.provisioned_gib = gib;
        let r = e.run_sleeper()?;
        t.row(&[
            format!("{gib} GiB"),
            spoton::util::fmt::dollars(r.storage_cost),
            spoton::util::fmt::dollars(r.total_cost()),
            format!("{:.1}%", (1.0 - r.total_cost() / od.total_cost()) * 100.0),
        ]);
    }
    print!("{}", t.render());

    // 3. Where does spot+ckpt stop being worth it? Sweep the spot
    //    discount by interpolating the spot price toward on-demand.
    println!("\nSpot-discount sensitivity (evict 60m, transparent 15m):\n");
    let mut t =
        TextTable::new(&["Spot discount", "Spot+ckpt total", "Still cheaper?"]);
    for discount in [0.8f64, 0.6, 0.4, 0.2, 0.05] {
        // emulate by scaling measured compute cost: compute scales
        // linearly with the hourly price
        let r = Experiment::table1()
            .eviction_every(SimDuration::from_mins(60))
            .transparent(SimDuration::from_mins(15))
            .run_sleeper()?;
        let spot_price = 0.38 * (1.0 - discount);
        let compute = r.total.as_hours_f64() * spot_price;
        let total = compute + r.storage_cost;
        t.row(&[
            format!("{:.0}%", discount * 100.0),
            spoton::util::fmt::dollars(total),
            if total < od.total_cost() { "yes" } else { "NO" }.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\n(paper's Azure discount is 80%: ${} vs ${} on-demand per hour)",
        0.076, 0.38
    );
    Ok(())
}
