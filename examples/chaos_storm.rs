//! Chaos storm: coordinated evictions + flaky storage vs the retrying
//! coordinator.
//!
//! ```bash
//! cargo run --release --example chaos_storm
//! ```
//!
//! Loads the `chaos-storm` scenario (the same TOML that CI drives
//! through `spoton check`): a two-pool fleet hit by seeded eviction
//! storms while the scheduled-events endpoint goes dark and checkpoint
//! commits fail at random. The run is hard-asserted through the
//! scenario's own `[expect]` section, then re-run with retries stripped
//! to show what the bounded-backoff coordinator absorbs.

use spoton::config::ScenarioConfig;
use spoton::metrics::EventKind;
use spoton::report::table::TextTable;
use spoton::report::{expect, faults};
use spoton::sim::experiment::Experiment;

fn main() -> anyhow::Result<()> {
    // The example and `spoton check` evaluate the identical scenario —
    // compiled in so it runs from any working directory.
    let cfg = ScenarioConfig::from_str_toml(include_str!(
        "../scenarios/chaos_storm.toml"
    ))?;
    let expect_cfg = cfg
        .expect
        .clone()
        .expect("chaos_storm.toml carries an [expect] section");

    // 1. The hardened coordinator, judged by its own expectations.
    println!("chaos-storm with bounded-backoff retries:\n");
    let exp = Experiment { cfg: cfg.clone() };
    let runs = exp
        .sweep()
        .seed_range(cfg.seed, expect_cfg.seeds as usize)
        .run()?;
    let acc = faults::account_many(runs.iter().map(|r| &r.result.timeline));
    print!("{}", faults::render(&acc));
    let report = expect::evaluate_runs(&expect_cfg, &cfg.name, &runs);
    print!("\n{}", expect::render(&report));
    assert!(report.passed(), "[expect] must hold under the storm");
    assert!(acc.total() > 0, "the storms alone guarantee chaos events");
    assert_eq!(
        acc.count(EventKind::UnrecoveredRestore),
        0,
        "every restore must land on a verified generation"
    );

    // 2. Same seeds, same faults drawn, retries stripped: every injected
    //    write fault now costs a whole generation instead of a delay.
    println!("\nsame storm, no-retry baseline:\n");
    let mut bare = cfg.clone();
    bare.retry = None;
    let baseline = Experiment { cfg: bare }
        .sweep()
        .seed_range(cfg.seed, expect_cfg.seeds as usize)
        .run()?;
    let bare_acc =
        faults::account_many(baseline.iter().map(|r| &r.result.timeline));

    let count = |rs: &[spoton::sim::sweep::SeededRun], k: EventKind| {
        rs.iter().map(|r| r.result.timeline.count(k)).sum::<usize>()
    };
    let mut t = TextTable::new(&[
        "Coordinator", "Retries", "Lost generations", "Completed",
    ]);
    for (label, rs, a) in
        [("retrying", &runs, &acc), ("no-retry", &baseline, &bare_acc)]
    {
        t.row(&[
            label.to_string(),
            a.count(EventKind::CkptRetried).to_string(),
            count(rs, EventKind::CheckpointFailed).to_string(),
            format!(
                "{}/{}",
                rs.iter().filter(|r| r.result.completed).count(),
                rs.len()
            ),
        ]);
    }
    print!("{}", t.render());

    let retry_lost = count(&runs, EventKind::CheckpointFailed);
    let bare_lost = count(&baseline, EventKind::CheckpointFailed);
    assert!(
        bare_lost >= retry_lost,
        "backoff may only reduce lost generations ({bare_lost} < {retry_lost})"
    );
    println!("\nstorm absorbed: zero unrecovered restores, [expect] green");
    Ok(())
}
