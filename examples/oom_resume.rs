//! OOM interrupt → resume on a larger instance (paper §IV).
//!
//! ```bash
//! cargo run --release --example oom_resume
//! ```
//!
//! "It can support other types of interruption, such as out-of-memory, in
//! which case the workload can be resumed on a larger instance from a
//! checkpoint."
//!
//! This example composes the framework's pieces directly (no experiment
//! driver): a workload runs on a D8s_v3 until it "OOMs" mid-stage, the
//! last periodic transparent checkpoint survives on the share, the scale
//! set is resized to the smallest size with enough memory, and the
//! replacement instance restores and finishes — with the bill showing the
//! mixed-size run.

use spoton::checkpoint::{CheckpointWriter, CkptKind};
use spoton::cloud::billing::BillingMeter;
use spoton::cloud::pricing::PriceBook;
use spoton::cloud::scale_set::ScaleSet;
use spoton::config::CheckpointMethodCfg;
use spoton::coordinator::{CheckpointPolicy, RestartManager};
use spoton::simclock::{Clock, SimDuration, SimTime};
use spoton::storage::BlobStore;
use spoton::workload::sleeper::{Sleeper, SleeperCfg};
use spoton::workload::Workload;

fn main() -> anyhow::Result<()> {
    let book = PriceBook::default();
    let mut clock = Clock::new();
    let mut billing = BillingMeter::new();
    let mut store = BlobStore::for_tests();
    let mut writer = CheckpointWriter::new();
    let policy = CheckpointPolicy::new(CheckpointMethodCfg::Transparent {
        interval: SimDuration::from_mins(30),
    });

    let mut scale_set = ScaleSet::new(
        "Standard_D8s_v3",
        true,
        SimDuration::from_secs(90),
        book.clone(),
    )?;

    // --- phase 1: run on the 32 GiB instance until it OOMs -------------
    let vm0 = scale_set.launch(clock.now()).id;
    println!("launched {vm0} (Standard_D8s_v3, 32 GiB)");
    let mut workload = Sleeper::new(SleeperCfg::small(), 77);
    let step_cost = SimDuration::from_secs(55);
    let mut last_ckpt = clock.now();
    let mut steps = 0u32;

    // the workload's memory footprint grows past 32 GiB at step 70
    let oom_at_step = 70u32;
    let oom_footprint_gib = 48u32;

    loop {
        if policy.periodic_due(clock.now(), last_ckpt) {
            let snap = workload.snapshot()?;
            let out = writer.write(
                &mut store,
                clock.now(),
                CkptKind::Periodic,
                &workload,
                &snap,
            )?;
            clock.advance(out.cost());
            last_ckpt = clock.now();
            println!(
                "  {:?} periodic checkpoint {} (step {steps})",
                clock.now(),
                out.committed().unwrap().id
            );
        }
        if steps == oom_at_step {
            println!(
                "  {:?} OOM: workload needs {oom_footprint_gib} GiB, \
                 instance has 32 GiB — killing {vm0}",
                clock.now()
            );
            break;
        }
        clock.advance(step_cost);
        workload.step()?;
        steps += 1;
    }
    let steps_at_oom = workload.progress().total_steps;
    scale_set.terminate_current(clock.now(), &mut billing);

    // --- phase 2: upsize and resume ------------------------------------
    let bigger = book
        .smallest_with_mem(oom_footprint_gib)
        .expect("catalog has a big enough size");
    println!(
        "resizing scale set: Standard_D8s_v3 -> {} ({} GiB)",
        bigger.name, bigger.mem_gib
    );
    scale_set.resize(&bigger.name)?;
    clock.advance(scale_set.provisioning_delay());
    let vm1 = scale_set.launch(clock.now()).id;
    println!("launched {vm1} ({}, {} GiB)", bigger.name, bigger.mem_gib);

    let mut resumed = Sleeper::new(SleeperCfg::small(), 77);
    let report =
        RestartManager::find_and_restore(&mut store, &policy, &mut resumed)?
            .expect("checkpoint must exist");
    clock.advance(report.cost);
    println!(
        "  {:?} restored from checkpoint {} (step {}, lost {} steps to \
         the OOM)",
        clock.now(),
        report.manifest.id,
        report.resumed_total_steps,
        steps_at_oom - report.resumed_total_steps,
    );

    while !resumed.is_done() {
        clock.advance(step_cost);
        resumed.step()?;
    }
    println!("  {:?} workload complete on the larger instance", clock.now());
    scale_set.terminate_current(clock.now(), &mut billing);

    // --- verify + bill ---------------------------------------------------
    let mut reference = Sleeper::new(SleeperCfg::small(), 77);
    while !reference.is_done() {
        reference.step()?;
    }
    assert_eq!(
        resumed.fingerprint(),
        reference.fingerprint(),
        "post-OOM resume diverged from uninterrupted execution"
    );
    billing.book_storage(
        "nfs-share",
        100.0,
        clock.now().since(SimTime::ZERO),
        16.0,
    );
    println!("\nInvoice (mixed instance sizes):\n{}", billing.invoice());
    println!(
        "RESULT: OOM survived; run resumed on {} and finished bit-exact.",
        bigger.name
    );
    Ok(())
}
