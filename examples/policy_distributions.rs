//! Placement-policy comparison as *distributions*, not point estimates.
//!
//! ```bash
//! cargo run --release --example policy_distributions
//! ```
//!
//! `examples/fleet_failover.rs` compares `sticky`, `cheapest-spot` and
//! `eviction-aware` on one seeded storm; a single eviction schedule can
//! flatter any policy. Here each policy runs the same three-pool fleet
//! over 1,000 sampled eviction processes (seeds 0..1000) on the parallel
//! sweep driver, and the makespan / cost distributions do the comparing:
//! `eviction-aware` should beat `sticky` not just on the mean but at the
//! tail (p95/p99), because abandoning the contended pool caps the
//! worst-case eviction cascade.

use spoton::config::{EvictionPlanCfg, PlacementPolicyCfg, PoolCfg};
use spoton::report::distribution::{self, SweepDistributions};
use spoton::sim::experiment::Experiment;
use spoton::simclock::SimDuration;
use std::time::Instant;

const SEEDS: usize = 1000;

fn storm_experiment(policy: PlacementPolicyCfg) -> Experiment {
    Experiment::table1()
        .named("policy-dist")
        .transparent(SimDuration::from_mins(15))
        .pool(
            PoolCfg::named("east-contended")
                .price_factor(0.9)
                .eviction(EvictionPlanCfg::Poisson {
                    mean: SimDuration::from_mins(20),
                })
                .provisioning_delay(SimDuration::from_mins(20)),
        )
        .pool(
            PoolCfg::named("south-balanced")
                .price_factor(1.0)
                .eviction(EvictionPlanCfg::Poisson {
                    mean: SimDuration::from_mins(45),
                })
                .provisioning_delay(SimDuration::from_secs(180)),
        )
        .pool(
            PoolCfg::named("west-stable")
                .price_factor(1.2)
                .provisioning_delay(SimDuration::from_secs(90)),
        )
        .placement(policy)
}

fn sweep_policy(label: &str, policy: PlacementPolicyCfg) -> SweepDistributions {
    let t0 = Instant::now();
    let runs = storm_experiment(policy)
        .sweep()
        .seed_range(0, SEEDS)
        .run()
        .expect("sweep run");
    let dist = distribution::summarize(label, &runs);
    println!(
        "\n== {label} ({} runs in {:.2?}) ==",
        SEEDS,
        t0.elapsed()
    );
    print!("{}", distribution::render(&dist));
    dist
}

fn main() -> anyhow::Result<()> {
    println!(
        "Three-pool storm fleet, {SEEDS} sampled eviction processes per \
         policy"
    );

    let sticky = sweep_policy("sticky", PlacementPolicyCfg::Sticky);
    let cheapest =
        sweep_policy("cheapest-spot", PlacementPolicyCfg::CheapestSpot);
    let aware = sweep_policy(
        "eviction-aware",
        PlacementPolicyCfg::EvictionAware { penalty: 4.0 },
    );

    println!("\n== head-to-head (makespan hours: mean / p95 / p99) ==");
    for d in [&sticky, &cheapest, &aware] {
        println!(
            "  {:<16} {:>6.2} / {:>6.2} / {:>6.2}   cost mean ${:.4}   \
             completed {}/{}",
            d.scenario,
            d.makespan_secs.mean / 3600.0,
            d.makespan_secs.p95 / 3600.0,
            d.makespan_secs.p99 / 3600.0,
            d.total_cost.mean,
            d.completed,
            d.runs,
        );
    }

    let tail_gain =
        1.0 - aware.makespan_secs.p95 / sticky.makespan_secs.p95.max(1.0);
    println!(
        "\neviction-aware vs sticky: mean makespan {:+.1}%, p95 {:+.1}%",
        100.0 * (aware.makespan_secs.mean / sticky.makespan_secs.mean - 1.0),
        -100.0 * tail_gain,
    );
    anyhow::ensure!(
        aware.makespan_secs.mean < sticky.makespan_secs.mean,
        "eviction-aware should beat sticky on mean makespan over the \
         population"
    );
    Ok(())
}
