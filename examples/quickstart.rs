//! Quickstart: protect a long-running workload on spot instances.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Builds the paper's Table I row-5 scenario — spot instance evicted
//! every 90 minutes, transparent checkpoints every 30 minutes — runs it
//! on the virtual clock, and prints what Spot-on did about it.

use spoton::sim::experiment::Experiment;
use spoton::simclock::SimDuration;

fn main() -> anyhow::Result<()> {
    // 1. Describe the run: the builder starts from the paper's testbed
    //    (Standard_D8s_v3, $0.076/h spot, Azure-Files-style NFS, 30 s
    //    eviction notice, metaSPAdes-calibrated stage durations).
    let experiment = Experiment::table1()
        .named("quickstart")
        .eviction_every(SimDuration::from_mins(90))
        .transparent(SimDuration::from_mins(30));

    // 2. Run it. The sleeper workload exercises the whole coordination
    //    stack (scale set, scheduled events, checkpoint engine, restart)
    //    in milliseconds of wall time; see examples/metaspades_spot.rs
    //    for the full PJRT-backed assembler.
    let result = experiment.run_sleeper()?;

    // 3. What happened?
    println!("{}\n", result.summary());
    println!("Per-stage wall time (cf. paper Table I row 5):");
    for (label, d) in &result.stage_times {
        println!("  {label:<6} {d}");
    }
    println!("\nWhat the coordinator did:");
    println!("  instances used          : {}", result.instances);
    println!("  evictions survived      : {}", result.evictions);
    println!("  periodic checkpoints    : {}", result.periodic_ckpts);
    println!("  termination checkpoints : {}", result.termination_ok);
    println!("  restores                : {}", result.restores);
    println!("\nInvoice:\n{}", result.invoice);

    // 4. The headline guarantee: the run completed despite evictions, at
    //    spot prices.
    assert!(result.completed);
    let ondemand = Experiment::table1().spoton_off().ondemand().run_sleeper()?;
    println!(
        "cost: {} vs {} on-demand  ({:.0}% saved)",
        spoton::util::fmt::dollars(result.total_cost()),
        spoton::util::fmt::dollars(ondemand.total_cost()),
        (1.0 - result.total_cost() / ondemand.total_cost()) * 100.0
    );
    Ok(())
}
