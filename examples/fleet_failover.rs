//! Multi-pool fleet failover: three pools with distinct price books and
//! eviction plans on one event queue, compared across placement policies.
//!
//! ```bash
//! cargo run --release --example fleet_failover
//! ```
//!
//! The fleet models a common spot-market shape:
//!
//! * `east-contended` — cheapest (0.9× the catalog), but heavily
//!   contended: evicted every 5 minutes of uptime and replacements take
//!   20 minutes (scarce capacity);
//! * `south-balanced` — catalog price, Poisson evictions (45 min mean),
//!   3-minute replacements;
//! * `west-stable`    — 1.2× the catalog, never reclaimed, 90-second
//!   replacements.
//!
//! `sticky` rides the cheap contended pool through every eviction (the
//! paper's single-scale-set behaviour), `cheapest-spot` keeps choosing it
//! on price alone, and `eviction-aware` abandons a pool after being
//! burned — finishing hours earlier and cheaper. Each run's compute cost
//! is attributed pool by pool; the attribution always sums to the run's
//! compute total.

use spoton::config::{EvictionPlanCfg, PlacementPolicyCfg, PoolCfg};
use spoton::report::fleet::{render_policy_comparison, render_pool_breakdown};
use spoton::sim::experiment::Experiment;
use spoton::sim::RunResult;
use spoton::simclock::SimDuration;

fn storm_experiment(policy: PlacementPolicyCfg) -> Experiment {
    Experiment::table1()
        .named("fleet-failover")
        .transparent(SimDuration::from_mins(15))
        .seed(42)
        .pool(
            PoolCfg::named("east-contended")
                .price_factor(0.9)
                .eviction(EvictionPlanCfg::Fixed {
                    interval: SimDuration::from_mins(5),
                })
                .provisioning_delay(SimDuration::from_mins(20)),
        )
        .pool(
            PoolCfg::named("south-balanced")
                .price_factor(1.0)
                .eviction(EvictionPlanCfg::Poisson {
                    mean: SimDuration::from_mins(45),
                })
                .provisioning_delay(SimDuration::from_secs(180)),
        )
        .pool(
            PoolCfg::named("west-stable")
                .price_factor(1.2)
                .provisioning_delay(SimDuration::from_secs(90)),
        )
        .placement(policy)
}

fn main() -> anyhow::Result<()> {
    let policies = [
        ("sticky", PlacementPolicyCfg::Sticky),
        ("cheapest-spot", PlacementPolicyCfg::CheapestSpot),
        ("eviction-aware", PlacementPolicyCfg::EvictionAware { penalty: 4.0 }),
    ];

    let mut results: Vec<(&str, RunResult)> = Vec::new();
    for (label, policy) in policies {
        let r = storm_experiment(policy).run_sleeper()?;
        results.push((label, r));
    }

    println!("Placement-policy comparison (same seeded eviction storm):\n");
    let rows: Vec<(&str, &RunResult)> =
        results.iter().map(|(l, r)| (*l, r)).collect();
    print!("{}", render_policy_comparison(&rows));

    for (label, r) in &results {
        println!("\nPer-pool attribution — {label}:\n");
        print!("{}", render_pool_breakdown(r));
        let attributed: f64 =
            r.pool_stats.iter().map(|p| p.compute_cost).sum();
        assert!(
            (attributed - r.compute_cost).abs() < 1e-9,
            "pool attribution must sum to the run's compute cost"
        );
    }

    let sticky = &results[0].1;
    let aware = &results[2].1;
    assert!(
        aware.total_cost() < sticky.total_cost(),
        "eviction-aware must beat sticky on this storm"
    );
    println!(
        "\neviction-aware vs sticky: {} vs {} makespan, ${:.4} vs ${:.4} \
         total — {:.0}% cheaper by refusing to re-queue into the pool \
         that keeps evicting it.",
        aware.total.hms(),
        sticky.total.hms(),
        aware.total_cost(),
        sticky.total_cost(),
        (1.0 - aware.total_cost() / sticky.total_cost()) * 100.0
    );
    Ok(())
}
